//! Minimal CLI argument parser (no clap in the offline vendor set):
//! subcommands with `--flag value` / `--flag` options and positional
//! arguments, plus help rendering.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// Parsed command line: subcommand, flags, positionals.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
    pub positional: Vec<String>,
}

/// Flags that take a value (everything else is a boolean switch).
const VALUE_FLAGS: &[&str] = &[
    "artifacts", "scenario", "variant", "m", "requests", "duration-s", "rate",
    "workers", "cache", "dso", "config", "bind", "trace", "seed", "concurrency",
    "executors", "theta", "catalog", "replicas", "policy", "deadline-ms",
    "slots", "users", "result-cache-cap", "result-ttl-ms", "dup-rate",
    "coalesce-wait-us", "m-dist", "feature-workers", "fetch-wait-us",
    "handoff-capacity", "backend", "threads", "trace-out", "trace-sample-n",
    "metrics-addr", "metrics-hold-s", "baseline", "src", "chaos", "chaos-seed",
    "tenants", "storm",
];

impl Args {
    /// Parse from an argv iterator (without the program name).
    pub fn parse<I: Iterator<Item = String>>(mut argv: I) -> Result<Args> {
        let mut a = Args::default();
        let mut pending: Option<String> = None;
        for tok in argv.by_ref() {
            if let Some(flag) = pending.take() {
                a.flags.insert(flag, tok);
                continue;
            }
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    a.flags.insert(k.to_string(), v.to_string());
                } else if VALUE_FLAGS.contains(&name) {
                    pending = Some(name.to_string());
                } else {
                    a.switches.push(name.to_string());
                }
            } else if a.subcommand.is_none() {
                a.subcommand = Some(tok);
            } else {
                a.positional.push(tok);
            }
        }
        if let Some(flag) = pending {
            return Err(Error::Config(format!("flag --{flag} expects a value")));
        }
        Ok(a)
    }

    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, flag: &str) -> Option<&str> {
        self.flags.get(flag).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, flag: &str, default: &'a str) -> &'a str {
        self.get(flag).unwrap_or(default)
    }

    pub fn get_parse<T: std::str::FromStr>(&self, flag: &str) -> Result<Option<T>> {
        match self.get(flag) {
            None => Ok(None),
            Some(s) => s
                .parse::<T>()
                .map(Some)
                .map_err(|_| Error::Config(format!("bad value for --{flag}: '{s}'"))),
        }
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }
}

/// Render the top-level help text.
pub fn help() -> String {
    "\
flame — serving system for large-scale generative recommendation (FLAME reproduction)

USAGE: flame <COMMAND> [flags]

COMMANDS:
  info      print scenarios, engines, FLOP envelope, NUMA topology
  serve     run the serving stack on synthetic traffic and report metrics
  replay    serve a recorded JSONL trace (--trace FILE)
  record    generate and save a trace (--trace FILE --requests N)
  trace-gen expand a storm scenario into a timed v2 trace
            (--trace FILE --storm SPEC --rate R --duration-s S)
  bind      start the TCP front (--bind ADDR; --replicas N fronts a cluster;
            --pipeline fronts the staged pipeline with client-gone
            cancellation; --duration-s S serves a bounded window then
            drains gracefully)
  cluster   drive the multi-replica cluster router and report per-replica
            metrics (simulated replicas by default; --real uses artifacts)
  trace-check  validate a --trace-out JSON file (schema + flow pairing)
            and print event counts: flame trace-check trace.json
  lint      self-hosted static analysis of this crate's sources: lock
            order, condvar discipline, no-alloc hot paths, panic
            policy, unsafe hygiene (CI gate; see LINT FLAGS)

LINT FLAGS:
  --src DIR           crate root to scan (default: auto-detect rust/)
  --baseline FILE     accepted-finding fingerprints (default:
                      <root>/lint_baseline.txt)
  --write-baseline    regenerate the baseline from current findings
  --graph             print the inferred lock-acquisition graph

CLUSTER FLAGS:
  --replicas N        replica count                (default: 3)
  --policy P          rr | p2c | affinity          (default: affinity)
  --deadline-ms D     per-request deadline budget  (default: 50)
  --slots N           service slots per replica    (default: 4)
  --users N           synthetic user population    (default: 2000)
  --result-cache-cap N  router result-cache entries, 0 = off (default: 32768)
  --result-ttl-ms T   result-cache freshness TTL   (default: 2000)
  --no-coalesce       disable single-flight coalescing of identical requests
  --dup-rate F        duplicate-burst rate injected into the synthetic
                      workload, 0.0..1.0           (default: 0)
  --real              replicas are real stacks (needs artifacts)
  --tenants SPEC      per-tenant SLA/weight overrides, e.g.
                      t1:w=3,sla_ms=20,t2:sla_ms=80 (unlisted tenants
                      keep weight 1 and the --deadline-ms budget)
  --controller        arm the per-tenant overload controller: AIMD
                      admission-blend tightening + weighted-fair shed
                      under pressure (brownout recovers when clean)

STORM FLAGS (cluster, trace-gen):
  --storm SPEC        non-stationary scenario clauses, e.g.
                      diurnal:period_s=10,amp=0.5,flash:tenant=1,at_s=2,
                      for_s=1,x=8,hot=64,invalidate:rate=500,at_s=2,
                      for_s=1,mix:w0=3,w1=1 (see EXPERIMENTS.md \"Storm
                      runbook\"). On `cluster` the timeline replays
                      through the timed driver; invalidation events call
                      the router's invalidate_user live.

COMMON FLAGS:
  --artifacts DIR     artifact directory (default: artifacts)
  --scenario NAME     tiny | bench | base | long   (default: bench)
  --variant NAME      naive | api | fused          (default: fused)
  --backend B         artifact-free compute backends: cpu (native CPU
                      FKE, honors --variant) | sim (deterministic
                      queueing sim); default: compiled PJRT artifacts
  --threads N         cpu backend: worker threads per engine launch
                      (default: auto)
  --cache MODE        off | async | sync           (default: async)
  --dso MODE          explicit | implicit          (default: explicit)
  --coalesce          pack concurrent requests' remainder rows into
                      shared engine launches (DSO batch coalescer)
  --coalesce-wait-us T  max µs a partial coalesce batch waits before
                      flushing                     (default: 200)
  --m-dist D          candidate-count distribution over the profile
                      support: uniform | bimodal | zipf
  --pipeline          decoupled two-stage serving: feature-stage workers
                      overlap the compute-stage engine launches
  --feature-workers N feature-stage workers in pipelined mode (default: 2)
  --handoff-capacity N bounded stage-handoff queue depth   (default: 8)
  --deadline-first    pipelined intake pops the nearest-deadline request
                      first instead of FIFO
  --cancel            cooperative cancellation: stamp each request's token
                      with its deadline so stage boundaries drop doomed
                      work early (typed Cancelled replies, counted per
                      cause x stage)
  --fetch-coalesce    single-flight concurrent feature-cache misses into
                      shared remote multiget batches (sync cache mode)
  --fetch-wait-us T   max µs a partial miss batch waits before flushing
                                                   (default: 150)
  --workers N         pipeline worker threads; in pipelined mode, the
                      compute-stage submitter count (default: 4)
  --executors N       executors per profile        (default: 1)
  --requests N        request count                (default: 64)
  --duration-s S      run duration seconds         (default: 10)
  --rate R            open-loop arrival rate/s (omit = closed loop)
  --no-numa           disable NUMA binding
  --no-staging        disable staging arenas
  --seed N            workload seed

CHAOS FLAGS (serve, cluster):
  --chaos SPEC        arm the fault-injection plane with a seeded plan,
                      e.g. store_timeout:p=0.05,brownout:replica=2,x=8
                      (clauses: store_delay, store_error, store_timeout,
                      brownout, crash, stall, panic — see EXPERIMENTS.md
                      \"Chaos runbook\" for the grammar). Arming also
                      enables the degradation ladder: retries with
                      backoff, hedged re-dispatch, and (serve) candidate
                      truncation for over-budget requests.
  --chaos-seed N      fault-plan RNG seed (default: 0 — same seed, same
                      storm, reproducible)

OBSERVABILITY FLAGS (serve, cluster):
  --trace-out FILE    write a Chrome trace-event / Perfetto JSON timeline
                      of sampled requests on exit (open in ui.perfetto.dev)
  --trace-sample-n N  head-sample 1-in-N requests for full span timelines
                      (default: 1 when --trace-out is set, else 0 = off;
                      SLA-miss exemplars are kept regardless)
  --metrics-addr ADDR serve live Prometheus-style text metrics over HTTP
                      at ADDR (e.g. 127.0.0.1:9095) for the run's duration
  --metrics-hold-s S  keep the metrics endpoint up S seconds after the
                      run ends (lets a scraper catch a short run)
"
    .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse(&["serve", "--scenario", "bench", "--workers", "8", "--no-numa"]);
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.get("scenario"), Some("bench"));
        assert_eq!(a.get_parse::<usize>("workers").unwrap(), Some(8));
        assert!(a.has("no-numa"));
        assert!(!a.has("no-staging"));
    }

    #[test]
    fn equals_syntax() {
        let a = parse(&["serve", "--scenario=long", "--rate=2500.5"]);
        assert_eq!(a.get("scenario"), Some("long"));
        assert_eq!(a.get_parse::<f64>("rate").unwrap(), Some(2500.5));
    }

    #[test]
    fn positionals() {
        let a = parse(&["record", "out.jsonl"]);
        assert_eq!(a.positional, vec!["out.jsonl"]);
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(["serve", "--scenario"].iter().map(|s| s.to_string())).is_err());
    }

    #[test]
    fn bad_parse_is_error() {
        let a = parse(&["serve", "--workers", "eight"]);
        assert!(a.get_parse::<usize>("workers").is_err());
    }

    #[test]
    fn defaults() {
        let a = parse(&["serve"]);
        assert_eq!(a.get_or("scenario", "bench"), "bench");
    }

    #[test]
    fn help_mentions_commands() {
        let h = help();
        for cmd in ["info", "serve", "replay", "record", "bind", "cluster"] {
            assert!(h.contains(cmd));
        }
    }

    #[test]
    fn cluster_flags_take_values() {
        let a = parse(&["cluster", "--replicas", "4", "--policy", "affinity", "--deadline-ms", "20"]);
        assert_eq!(a.get_parse::<usize>("replicas").unwrap(), Some(4));
        assert_eq!(a.get("policy"), Some("affinity"));
        assert_eq!(a.get_parse::<u64>("deadline-ms").unwrap(), Some(20));
    }

    #[test]
    fn coalesce_flags_parse() {
        let a = parse(&["serve", "--coalesce", "--coalesce-wait-us", "500", "--m-dist", "zipf"]);
        assert!(a.has("coalesce"));
        assert_eq!(a.get_parse::<u64>("coalesce-wait-us").unwrap(), Some(500));
        assert_eq!(a.get("m-dist"), Some("zipf"));
    }

    #[test]
    fn help_mentions_coalescer() {
        let h = help();
        assert!(h.contains("--coalesce"));
        assert!(h.contains("--m-dist"));
    }

    #[test]
    fn pipeline_flags_parse() {
        let a = parse(&[
            "serve",
            "--pipeline",
            "--feature-workers",
            "3",
            "--handoff-capacity",
            "16",
            "--fetch-coalesce",
            "--fetch-wait-us",
            "250",
        ]);
        assert!(a.has("pipeline"));
        assert_eq!(a.get_parse::<usize>("feature-workers").unwrap(), Some(3));
        assert_eq!(a.get_parse::<usize>("handoff-capacity").unwrap(), Some(16));
        assert!(a.has("fetch-coalesce"));
        assert_eq!(a.get_parse::<u64>("fetch-wait-us").unwrap(), Some(250));
    }

    #[test]
    fn help_mentions_pipeline() {
        let h = help();
        assert!(h.contains("--pipeline"));
        assert!(h.contains("--feature-workers"));
        assert!(h.contains("--fetch-coalesce"));
        assert!(h.contains("--fetch-wait-us"));
        assert!(h.contains("--deadline-first"));
    }

    #[test]
    fn backend_flags_take_values() {
        let a = parse(&["serve", "--backend", "cpu", "--variant", "api", "--threads", "4"]);
        assert_eq!(a.get("backend"), Some("cpu"));
        assert_eq!(a.get("variant"), Some("api"));
        assert_eq!(a.get_parse::<usize>("threads").unwrap(), Some(4));
        assert!(help().contains("--backend"));
    }

    #[test]
    fn deadline_first_is_a_switch() {
        let a = parse(&["serve", "--pipeline", "--deadline-first"]);
        assert!(a.has("deadline-first"));
        assert!(!a.has("deadline-ms"), "deadline-ms stays a value flag");
    }

    #[test]
    fn observability_flags_take_values() {
        let a = parse(&[
            "serve",
            "--trace-out",
            "trace.json",
            "--trace-sample-n",
            "8",
            "--metrics-addr",
            "127.0.0.1:9095",
        ]);
        assert_eq!(a.get("trace-out"), Some("trace.json"));
        assert_eq!(a.get_parse::<u64>("trace-sample-n").unwrap(), Some(8));
        assert_eq!(a.get("metrics-addr"), Some("127.0.0.1:9095"));
        let h = help();
        assert!(h.contains("--trace-out"));
        assert!(h.contains("--metrics-addr"));
        assert!(h.contains("trace-check"));
    }

    #[test]
    fn lint_flags_parse() {
        let a = parse(&["lint", "--baseline", "lint_baseline.txt", "--write-baseline", "--graph"]);
        assert_eq!(a.subcommand.as_deref(), Some("lint"));
        assert_eq!(a.get("baseline"), Some("lint_baseline.txt"));
        assert!(a.has("write-baseline"));
        assert!(a.has("graph"));
        let h = help();
        assert!(h.contains("lint"));
        assert!(h.contains("--write-baseline"));
        assert!(h.contains("--graph"));
    }

    #[test]
    fn chaos_flags_take_values() {
        let a = parse(&["cluster", "--chaos", "brownout:replica=1,x=4", "--chaos-seed", "7"]);
        assert_eq!(a.get("chaos"), Some("brownout:replica=1,x=4"));
        assert_eq!(a.get_parse::<u64>("chaos-seed").unwrap(), Some(7));
        let h = help();
        assert!(h.contains("--chaos"));
        assert!(h.contains("Chaos runbook"));
    }

    #[test]
    fn tenancy_and_storm_flags() {
        let a = parse(&[
            "cluster",
            "--tenants",
            "t1:w=3,sla_ms=20",
            "--controller",
            "--storm",
            "flash:tenant=1,at_s=2,for_s=1,x=8",
        ]);
        assert_eq!(a.get("tenants"), Some("t1:w=3,sla_ms=20"));
        assert!(a.has("controller"), "--controller is a bare switch");
        assert_eq!(a.get("storm"), Some("flash:tenant=1,at_s=2,for_s=1,x=8"));
        let h = help();
        assert!(h.contains("--tenants"));
        assert!(h.contains("--storm"));
        assert!(h.contains("trace-gen"));
        assert!(h.contains("Storm runbook"));
    }

    #[test]
    fn result_cache_flags_take_values() {
        let a = parse(&[
            "cluster",
            "--result-cache-cap",
            "4096",
            "--result-ttl-ms",
            "500",
            "--dup-rate",
            "0.25",
            "--no-coalesce",
        ]);
        assert_eq!(a.get_parse::<usize>("result-cache-cap").unwrap(), Some(4096));
        assert_eq!(a.get_parse::<u64>("result-ttl-ms").unwrap(), Some(500));
        assert_eq!(a.get_parse::<f64>("dup-rate").unwrap(), Some(0.25));
        assert!(a.has("no-coalesce"));
    }
}
