//! Load drivers: open-loop (Poisson arrivals at a target rate — the
//! production-like mode, exposes queueing) and closed-loop (fixed
//! concurrency, the throughput-probing mode the ablation benches use to
//! saturate an arm fairly).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::cluster::ClusterRouter;
use crate::util::rng::Rng;

use super::Request;

/// Summary of one driven run.
#[derive(Clone, Debug)]
pub struct DriveReport {
    pub submitted: u64,
    pub completed: u64,
    pub rejected: u64,
    pub elapsed: Duration,
}

/// Closed-loop driver: `concurrency` worker threads each pull the next
/// request from the shared iterator and call `serve` synchronously,
/// until `duration` elapses or the request list is exhausted.
pub fn closed_loop<F>(
    requests: Vec<Request>,
    concurrency: usize,
    duration: Duration,
    serve: F,
) -> DriveReport
where
    F: Fn(&Request) -> bool + Send + Sync,
{
    let serve = &serve;
    let next = AtomicU64::new(0);
    let completed = AtomicU64::new(0);
    let rejected = AtomicU64::new(0);
    let start = Instant::now();
    let n = requests.len() as u64;
    std::thread::scope(|s| {
        for _ in 0..concurrency.max(1) {
            s.spawn(|| loop {
                if start.elapsed() >= duration {
                    return;
                }
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    return;
                }
                if serve(&requests[i as usize]) {
                    completed.fetch_add(1, Ordering::Relaxed);
                } else {
                    rejected.fetch_add(1, Ordering::Relaxed);
                }
            });
        }
    });
    DriveReport {
        submitted: next.load(Ordering::Relaxed).min(n),
        completed: completed.load(Ordering::Relaxed),
        rejected: rejected.load(Ordering::Relaxed),
        elapsed: start.elapsed(),
    }
}

/// Open-loop driver: submits requests at Poisson-process arrival times
/// with rate `lambda` (req/s), dispatching each onto a scoped thread so
/// slow requests do not hold back the arrival process. `max_in_flight`
/// bounds dispatch concurrency (beyond it, arrivals are *rejected* —
/// admission control at the front door).
pub fn open_loop<F>(
    requests: Vec<Request>,
    lambda: f64,
    duration: Duration,
    max_in_flight: usize,
    seed: u64,
    serve: F,
) -> DriveReport
where
    F: Fn(&Request) -> bool + Send + Sync,
{
    let serve = &serve;
    let completed = AtomicU64::new(0);
    let rejected = AtomicU64::new(0);
    let in_flight = Arc::new(AtomicU64::new(0));
    let mut rng = Rng::new(seed);
    let start = Instant::now();
    let mut submitted = 0u64;

    std::thread::scope(|s| {
        let mut t_next = 0.0f64;
        for req in &requests {
            t_next += rng.exp(lambda);
            let target = Duration::from_secs_f64(t_next);
            if target >= duration {
                break;
            }
            let now = start.elapsed();
            if target > now {
                std::thread::sleep(target - now);
            }
            submitted += 1;
            if in_flight.load(Ordering::Relaxed) >= max_in_flight as u64 {
                rejected.fetch_add(1, Ordering::Relaxed);
                continue;
            }
            in_flight.fetch_add(1, Ordering::Relaxed);
            let inf = Arc::clone(&in_flight);
            let completed = &completed;
            let rejected = &rejected;
            s.spawn(move || {
                if serve(req) {
                    completed.fetch_add(1, Ordering::Relaxed);
                } else {
                    rejected.fetch_add(1, Ordering::Relaxed);
                }
                inf.fetch_sub(1, Ordering::Relaxed);
            });
        }
    });
    DriveReport {
        submitted,
        completed: completed.load(Ordering::Relaxed),
        rejected: rejected.load(Ordering::Relaxed),
        elapsed: start.elapsed(),
    }
}

/// Open-loop driver over the cluster tier: Poisson arrivals at `lambda`
/// req/s submitted through the router, which applies its own
/// deadline-aware admission (shed requests count as rejections in the
/// report; see `router.admission` for the shed/SLA-miss split). Each
/// submitted request's budget is the router's default deadline.
pub fn open_loop_cluster(
    router: &ClusterRouter,
    requests: Vec<Request>,
    lambda: f64,
    duration: Duration,
    max_in_flight: usize,
    seed: u64,
) -> DriveReport {
    open_loop(requests, lambda, duration, max_in_flight, seed, |r| router.submit(r).is_ok())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reqs(n: usize) -> Vec<Request> {
        (0..n)
            .map(|i| Request {
                request_id: i as u64,
                user_id: 0,
                history: vec![],
                candidates: vec![1, 2],
            })
            .collect()
    }

    #[test]
    fn closed_loop_serves_all_when_time_allows() {
        let r = closed_loop(reqs(100), 4, Duration::from_secs(5), |_| true);
        assert_eq!(r.submitted, 100);
        assert_eq!(r.completed, 100);
        assert_eq!(r.rejected, 0);
    }

    #[test]
    fn closed_loop_counts_rejections() {
        let r = closed_loop(reqs(50), 2, Duration::from_secs(5), |rq| rq.request_id % 2 == 0);
        assert_eq!(r.completed, 25);
        assert_eq!(r.rejected, 25);
    }

    #[test]
    fn closed_loop_respects_deadline() {
        let r = closed_loop(reqs(1_000_000), 2, Duration::from_millis(50), |_| {
            std::thread::sleep(Duration::from_millis(1));
            true
        });
        assert!(r.submitted < 1_000_000);
        assert!(r.elapsed < Duration::from_millis(500));
    }

    #[test]
    fn open_loop_rate_roughly_matched() {
        let lambda = 2_000.0;
        let r = open_loop(reqs(10_000), lambda, Duration::from_millis(300), 64, 1, |_| true);
        let rate = r.submitted as f64 / r.elapsed.as_secs_f64();
        assert!(rate > lambda * 0.5 && rate < lambda * 1.5, "rate {rate}");
    }

    #[test]
    fn open_loop_cluster_drives_router() {
        use crate::cluster::{ClusterConfig, ClusterRouter, ReplicaBackend, SimConfig, SimReplica};
        let backends: Vec<Arc<dyn ReplicaBackend>> = (0..2)
            .map(|_| {
                Arc::new(SimReplica::new(SimConfig {
                    base_us: 0,
                    per_pair_ns: 0,
                    miss_penalty_us: 0,
                    ..SimConfig::default()
                })) as Arc<dyn ReplicaBackend>
            })
            .collect();
        let router = ClusterRouter::new(backends, ClusterConfig::default()).unwrap();
        let r = open_loop_cluster(
            &router,
            reqs(500),
            5_000.0,
            Duration::from_millis(200),
            256,
            3,
        );
        assert!(r.completed > 0, "{r:?}");
        assert_eq!(r.completed, router.metrics.requests());
    }

    #[test]
    fn open_loop_sheds_above_concurrency_cap() {
        // serve blocks 50ms; at 1000 req/s with cap 2 almost everything
        // past the first few must be rejected.
        let r = open_loop(reqs(1_000), 1_000.0, Duration::from_millis(200), 2, 1, |_| {
            std::thread::sleep(Duration::from_millis(50));
            true
        });
        assert!(r.rejected > r.completed, "{r:?}");
    }
}
