//! Load drivers: open-loop (Poisson arrivals at a target rate — the
//! production-like mode, exposes queueing) and closed-loop (fixed
//! concurrency, the throughput-probing mode the ablation benches use to
//! saturate an arm fairly).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::cluster::ClusterRouter;
use crate::util::rng::Rng;

use super::Request;

/// Summary of one driven run.
#[derive(Clone, Debug)]
pub struct DriveReport {
    pub submitted: u64,
    pub completed: u64,
    pub rejected: u64,
    pub elapsed: Duration,
}

/// Closed-loop driver: `concurrency` worker threads each pull the next
/// request from the shared iterator and call `serve` synchronously,
/// until `duration` elapses or the request list is exhausted.
pub fn closed_loop<F>(
    requests: Vec<Request>,
    concurrency: usize,
    duration: Duration,
    serve: F,
) -> DriveReport
where
    F: Fn(&Request) -> bool + Send + Sync,
{
    let serve = &serve;
    let next = AtomicU64::new(0);
    let completed = AtomicU64::new(0);
    let rejected = AtomicU64::new(0);
    let start = Instant::now();
    let n = requests.len() as u64;
    std::thread::scope(|s| {
        for _ in 0..concurrency.max(1) {
            s.spawn(|| loop {
                if start.elapsed() >= duration {
                    return;
                }
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    return;
                }
                if serve(&requests[i as usize]) {
                    completed.fetch_add(1, Ordering::Relaxed);
                } else {
                    rejected.fetch_add(1, Ordering::Relaxed);
                }
            });
        }
    });
    DriveReport {
        submitted: next.load(Ordering::Relaxed).min(n),
        completed: completed.load(Ordering::Relaxed),
        rejected: rejected.load(Ordering::Relaxed),
        elapsed: start.elapsed(),
    }
}

/// Open-loop driver: submits requests at Poisson-process arrival times
/// with rate `lambda` (req/s), dispatching each onto a scoped thread so
/// slow requests do not hold back the arrival process. `max_in_flight`
/// bounds dispatch concurrency (beyond it, arrivals are *rejected* —
/// admission control at the front door).
pub fn open_loop<F>(
    requests: Vec<Request>,
    lambda: f64,
    duration: Duration,
    max_in_flight: usize,
    seed: u64,
    serve: F,
) -> DriveReport
where
    F: Fn(&Request) -> bool + Send + Sync,
{
    let serve = &serve;
    let completed = AtomicU64::new(0);
    let rejected = AtomicU64::new(0);
    let in_flight = Arc::new(AtomicU64::new(0));
    let mut rng = Rng::new(seed);
    let start = Instant::now();
    let mut submitted = 0u64;

    std::thread::scope(|s| {
        let mut t_next = 0.0f64;
        for req in &requests {
            t_next += rng.exp(lambda);
            let target = Duration::from_secs_f64(t_next);
            if target >= duration {
                break;
            }
            let now = start.elapsed();
            if target > now {
                std::thread::sleep(target - now);
            }
            submitted += 1;
            if in_flight.load(Ordering::Relaxed) >= max_in_flight as u64 {
                rejected.fetch_add(1, Ordering::Relaxed);
                continue;
            }
            in_flight.fetch_add(1, Ordering::Relaxed);
            let inf = Arc::clone(&in_flight);
            let completed = &completed;
            let rejected = &rejected;
            s.spawn(move || {
                if serve(req) {
                    completed.fetch_add(1, Ordering::Relaxed);
                } else {
                    rejected.fetch_add(1, Ordering::Relaxed);
                }
                inf.fetch_sub(1, Ordering::Relaxed);
            });
        }
    });
    DriveReport {
        submitted,
        completed: completed.load(Ordering::Relaxed),
        rejected: rejected.load(Ordering::Relaxed),
        elapsed: start.elapsed(),
    }
}

/// Duplicate-burst window: a re-issued request copies one of this many
/// immediately preceding requests — "the upstream retriever re-sent a
/// near-identical candidate set seconds later", which is the traffic
/// shape the router's result cache and single-flight coalescing target.
pub const DUP_WINDOW: usize = 64;

/// Rewrite a request stream so that, with probability `dup_rate`, a
/// request is an exact duplicate (fresh `request_id`, same user /
/// history / candidates) of one of the previous [`DUP_WINDOW`]
/// requests. `dup_rate <= 0` leaves the stream untouched; the rewrite
/// is deterministic in `seed`.
pub fn inject_duplicates(requests: &mut [Request], dup_rate: f64, seed: u64) {
    if dup_rate <= 0.0 || requests.len() < 2 {
        return;
    }
    let mut rng = Rng::new(seed ^ 0xD0_D0_CA_CA);
    for i in 1..requests.len() {
        if rng.next_f64() < dup_rate {
            let lo = i.saturating_sub(DUP_WINDOW);
            let j = lo + (rng.next_u64() as usize) % (i - lo);
            let id = requests[i].request_id;
            let mut dup = requests[j].clone();
            dup.request_id = id;
            requests[i] = dup;
        }
    }
}

/// Open-loop driver over the cluster tier: Poisson arrivals at `lambda`
/// req/s submitted through the router, which applies its own
/// deadline-aware admission (shed requests count as rejections in the
/// report; see `router.admission` for the shed/SLA-miss split). Each
/// submitted request's budget is the router's default deadline.
/// `dup_rate` injects duplicate bursts into the stream (see
/// [`inject_duplicates`]); pass 0.0 for the untouched workload.
pub fn open_loop_cluster(
    router: &ClusterRouter,
    mut requests: Vec<Request>,
    lambda: f64,
    duration: Duration,
    max_in_flight: usize,
    seed: u64,
    dup_rate: f64,
) -> DriveReport {
    inject_duplicates(&mut requests, dup_rate, seed);
    open_loop(requests, lambda, duration, max_in_flight, seed, |r| router.submit(r).is_ok())
}

/// Open-loop driver over the decoupled two-stage pipeline: Poisson
/// arrivals enqueued into the pipeline's intake (responses are consumed
/// by the compute stage's recorder; rejections are intake sheds — the
/// handoff backpressure surfacing at the front door). Unlike the
/// synchronous open-loop mode there is no per-request dispatch thread:
/// the pipeline's own stage workers provide all the concurrency, so the
/// arrival process never stalls behind a slow request.
pub fn open_loop_pipeline(
    handle: &crate::server::PipelineHandle,
    requests: Vec<Request>,
    lambda: f64,
    duration: Duration,
    seed: u64,
) -> DriveReport {
    let mut rng = Rng::new(seed);
    let start = Instant::now();
    let (mut submitted, mut completed, mut rejected) = (0u64, 0u64, 0u64);
    let mut t_next = 0.0f64;
    for req in requests {
        t_next += rng.exp(lambda);
        let target = Duration::from_secs_f64(t_next);
        if target >= duration {
            break;
        }
        let now = start.elapsed();
        if target > now {
            std::thread::sleep(target - now);
        }
        submitted += 1;
        match handle.enqueue(req) {
            Ok(()) => completed += 1,
            Err(_) => rejected += 1,
        }
    }
    DriveReport { submitted, completed, rejected, elapsed: start.elapsed() }
}

/// Timed-replay driver: plays a [`TraceEvent`] timeline (see
/// `workload::storm`) against `serve`, honoring each event's recorded
/// arrival offset so a storm's shape — flash-crowd ramps, diurnal
/// swings, invalidation bursts — survives into the live run. `time_scale`
/// stretches (>1) or compresses (<1) the recorded clock; arrivals
/// dispatch on scoped threads under the `max_in_flight` front-door cap
/// (breach = rejection, as in [`open_loop`]) while invalidation events
/// call `invalidate` inline on the arrival thread, preserving their
/// order against subsequent arrivals.
pub fn open_loop_events<F, G>(
    events: &[crate::workload::trace::TraceEvent],
    time_scale: f64,
    max_in_flight: usize,
    serve: F,
    invalidate: G,
) -> DriveReport
where
    F: Fn(&Request) -> bool + Send + Sync,
    G: Fn(u64) + Send + Sync,
{
    use crate::workload::trace::TraceEvent;
    let serve = &serve;
    let completed = AtomicU64::new(0);
    let rejected = AtomicU64::new(0);
    let in_flight = Arc::new(AtomicU64::new(0));
    let start = Instant::now();
    let mut submitted = 0u64;
    std::thread::scope(|s| {
        for e in events {
            let target = Duration::from_secs_f64(e.at_us() as f64 * 1e-6 * time_scale.max(0.0));
            let now = start.elapsed();
            if target > now {
                std::thread::sleep(target - now);
            }
            match e {
                TraceEvent::InvalidateUser { user_id, .. } => invalidate(*user_id),
                TraceEvent::Arrival { req, .. } => {
                    submitted += 1;
                    if in_flight.load(Ordering::Relaxed) >= max_in_flight as u64 {
                        rejected.fetch_add(1, Ordering::Relaxed);
                        continue;
                    }
                    in_flight.fetch_add(1, Ordering::Relaxed);
                    let inf = Arc::clone(&in_flight);
                    let completed = &completed;
                    let rejected = &rejected;
                    s.spawn(move || {
                        if serve(req) {
                            completed.fetch_add(1, Ordering::Relaxed);
                        } else {
                            rejected.fetch_add(1, Ordering::Relaxed);
                        }
                        inf.fetch_sub(1, Ordering::Relaxed);
                    });
                }
            }
        }
    });
    DriveReport {
        submitted,
        completed: completed.load(Ordering::Relaxed),
        rejected: rejected.load(Ordering::Relaxed),
        elapsed: start.elapsed(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reqs(n: usize) -> Vec<Request> {
        (0..n)
            .map(|i| Request {
                request_id: i as u64,
                user_id: 0,
                history: vec![],
                candidates: vec![1, 2],
                ..Default::default()
            })
            .collect()
    }

    #[test]
    fn closed_loop_serves_all_when_time_allows() {
        let r = closed_loop(reqs(100), 4, Duration::from_secs(5), |_| true);
        assert_eq!(r.submitted, 100);
        assert_eq!(r.completed, 100);
        assert_eq!(r.rejected, 0);
    }

    #[test]
    fn closed_loop_counts_rejections() {
        let r = closed_loop(reqs(50), 2, Duration::from_secs(5), |rq| rq.request_id % 2 == 0);
        assert_eq!(r.completed, 25);
        assert_eq!(r.rejected, 25);
    }

    #[test]
    fn closed_loop_respects_deadline() {
        let r = closed_loop(reqs(1_000_000), 2, Duration::from_millis(50), |_| {
            std::thread::sleep(Duration::from_millis(1));
            true
        });
        assert!(r.submitted < 1_000_000);
        assert!(r.elapsed < Duration::from_millis(500));
    }

    #[test]
    fn open_loop_rate_roughly_matched() {
        let lambda = 2_000.0;
        let r = open_loop(reqs(10_000), lambda, Duration::from_millis(300), 64, 1, |_| true);
        let rate = r.submitted as f64 / r.elapsed.as_secs_f64();
        assert!(rate > lambda * 0.5 && rate < lambda * 1.5, "rate {rate}");
    }

    #[test]
    fn open_loop_cluster_drives_router() {
        use crate::cluster::{ClusterConfig, ClusterRouter, ReplicaBackend, SimConfig, SimReplica};
        let backends: Vec<Arc<dyn ReplicaBackend>> = (0..2)
            .map(|_| {
                Arc::new(SimReplica::new(SimConfig {
                    base_us: 0,
                    per_pair_ns: 0,
                    miss_penalty_us: 0,
                    ..SimConfig::default()
                })) as Arc<dyn ReplicaBackend>
            })
            .collect();
        let router = ClusterRouter::new(backends, ClusterConfig::default()).unwrap();
        let r = open_loop_cluster(
            &router,
            reqs(500),
            5_000.0,
            Duration::from_millis(200),
            256,
            3,
            0.0,
        );
        assert!(r.completed > 0, "{r:?}");
        assert_eq!(r.completed, router.metrics.requests());
    }

    #[test]
    fn inject_duplicates_rewrites_roughly_at_rate() {
        let mut reqs: Vec<Request> = (0..2_000)
            .map(|i| Request {
                request_id: i as u64,
                user_id: i as u64,
                history: vec![i as u64],
                candidates: vec![i as u64, i as u64 + 1],
                ..Default::default()
            })
            .collect();
        let originals = reqs.clone();
        inject_duplicates(&mut reqs, 0.3, 11);
        let mut dup_count = 0usize;
        for (i, r) in reqs.iter().enumerate() {
            // ids are untouched either way
            assert_eq!(r.request_id, originals[i].request_id);
            if r.user_id != originals[i].user_id {
                dup_count += 1;
                // a rewritten request is an exact copy of an earlier
                // original, fresh id aside (chains of duplicates may
                // reach past one window, but never forward)
                let j = r.user_id as usize;
                assert!(j < i, "dup at {i} copied {j}");
                assert_eq!(r.candidates, originals[j].candidates);
                assert_eq!(r.history, originals[j].history);
            }
        }
        // Binomial(1999, 0.3) ≈ 600 ± 21 — wide margins, no flake
        assert!(
            (450..750).contains(&dup_count),
            "expected ~600 rewrites at 30%, saw {dup_count}"
        );
    }

    #[test]
    fn open_loop_cluster_dup_rate_feeds_result_cache() {
        use crate::cluster::{
            ClusterConfig, ClusterRouter, ReplicaBackend, ResultCacheConfig, SimConfig,
            SimReplica,
        };
        let backends: Vec<Arc<dyn ReplicaBackend>> = (0..2)
            .map(|_| {
                Arc::new(SimReplica::new(SimConfig {
                    base_us: 0,
                    per_pair_ns: 0,
                    miss_penalty_us: 0,
                    ..SimConfig::default()
                })) as Arc<dyn ReplicaBackend>
            })
            .collect();
        let cfg = ClusterConfig {
            result_cache: ResultCacheConfig {
                capacity: 4_096,
                ttl_ms: 60_000,
                ..ResultCacheConfig::default()
            },
            ..ClusterConfig::default()
        };
        let router = ClusterRouter::new(backends, cfg).unwrap();
        // distinct users so only injected duplicates can repeat a key
        let requests: Vec<Request> = (0..400)
            .map(|i| Request {
                request_id: i,
                user_id: i,
                history: vec![i],
                candidates: vec![i, i + 1],
                ..Default::default()
            })
            .collect();
        let r = open_loop_cluster(
            &router,
            requests,
            20_000.0,
            Duration::from_secs(5),
            1_024,
            7,
            0.5,
        );
        assert!(r.completed > 0, "{r:?}");
        let snap = router.snapshot();
        assert!(
            snap.result_hits + snap.result_coalesced > 0,
            "a 50% duplicate stream must produce result-tier hits, got {snap:?}"
        );
    }

    #[test]
    fn inject_duplicates_zero_rate_is_identity() {
        let mut reqs = reqs(50);
        let before = reqs.clone();
        inject_duplicates(&mut reqs, 0.0, 1);
        assert_eq!(reqs, before);
    }

    #[test]
    fn open_loop_events_replays_arrivals_and_invalidations() {
        use crate::workload::trace::TraceEvent;
        let rs = reqs(2);
        let events = vec![
            TraceEvent::Arrival { at_us: 0, req: rs[0].clone() },
            TraceEvent::InvalidateUser { at_us: 1_000, user_id: 42 },
            TraceEvent::Arrival { at_us: 2_000, req: rs[1].clone() },
        ];
        let invalidated = std::sync::Mutex::new(Vec::new());
        let r = open_loop_events(&events, 1.0, 16, |_| true, |u| {
            invalidated.lock().unwrap().push(u)
        });
        assert_eq!(r.submitted, 2);
        assert_eq!(r.completed, 2);
        assert_eq!(r.rejected, 0);
        assert_eq!(invalidated.lock().unwrap().as_slice(), &[42]);
        // the recorded 2ms span is honored (loosely — scheduling jitter)
        assert!(r.elapsed >= Duration::from_micros(2_000), "{:?}", r.elapsed);
    }

    #[test]
    fn open_loop_sheds_above_concurrency_cap() {
        // serve blocks 50ms; at 1000 req/s with cap 2 almost everything
        // past the first few must be rejected.
        let r = open_loop(reqs(1_000), 1_000.0, Duration::from_millis(200), 2, 1, |_| {
            std::thread::sleep(Duration::from_millis(50));
            true
        });
        assert!(r.rejected > r.completed, "{r:?}");
    }
}
