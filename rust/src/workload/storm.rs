//! Composable non-stationary storm scenarios — the workload engine that
//! proves the overload control plane. A [`StormSpec`] is parsed from a
//! clause grammar (same shape as `--chaos`) and expanded by
//! [`StormSpec::generate`] into a deterministic [`TraceEvent`] timeline:
//! diurnal load cycles, per-tenant flash crowds concentrated on hot
//! candidate sets, feature-update invalidation storms (driving
//! `ClusterRouter::invalidate_user` at replay), and multi-tenant mixes.
//! The timeline round-trips through the JSONL trace layer, so every arm
//! of an experiment sees the *identical* storm.
//!
//! # Grammar
//!
//! Comma-separated clauses; a clause is `name` or `name:key=value` with
//! further `key=value` tokens attaching to the last clause:
//!
//! ```text
//! diurnal:period_s=10,amp=0.5        sinusoidal rate modulation, factor in [1-amp, 1+amp]
//! flash:tenant=1,at_s=2,for_s=1,x=8,hot=64
//!                                    tenant 1's arrival rate ×8 during [2s, 3s),
//!                                    candidates drawn from the 64 hottest items
//! invalidate:rate=500,at_s=2,for_s=1 feature-update storm: 500 invalidations/s
//!                                    over already-seen users during [2s, 3s)
//! mix:w0=3,w1=1                      tenant share weights (tenants with weight 0
//!                                    generate no traffic; default: tenant 0 only)
//! ```
//!
//! Arrivals are drawn by thinning a homogeneous Poisson process at each
//! tenant's peak rate, so the expansion is exact for any composition of
//! clauses and deterministic given `(spec, seed, workload config)`.

use crate::error::{Error, Result};
use crate::util::rng::Rng;

use super::trace::TraceEvent;
use super::{Generator, Request, TenantId, MAX_TENANTS};

/// Sinusoidal diurnal load cycle.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Diurnal {
    pub period_s: f64,
    /// Modulation depth in [0, 1]: rate factor swings over [1-amp, 1+amp].
    pub amp: f64,
}

/// A flash crowd: one tenant's rate multiplied by `x` inside a window,
/// with candidates concentrated on the `hot` hottest catalog items.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Flash {
    pub tenant: TenantId,
    pub at_s: f64,
    pub for_s: f64,
    pub x: f64,
    /// Hot-set size; 0 leaves candidate sampling unchanged.
    pub hot: usize,
}

/// A feature-update invalidation storm: `rate` user invalidations per
/// second inside the window, targeting users already seen in the stream.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Invalidate {
    pub rate: f64,
    pub at_s: f64,
    pub for_s: f64,
}

/// Parsed storm scenario. [`StormSpec::generate`] expands it against a
/// [`Generator`] into a replayable event timeline.
#[derive(Clone, Debug, PartialEq)]
pub struct StormSpec {
    pub diurnal: Option<Diurnal>,
    pub flashes: Vec<Flash>,
    pub invalidations: Vec<Invalidate>,
    /// Per-tenant traffic share weights; all-zero is rejected at parse.
    pub weights: [f64; MAX_TENANTS],
}

impl Default for StormSpec {
    fn default() -> Self {
        let mut weights = [0.0; MAX_TENANTS];
        weights[0] = 1.0;
        StormSpec { diurnal: None, flashes: Vec::new(), invalidations: Vec::new(), weights }
    }
}

impl StormSpec {
    /// Stationary single-tenant traffic (no clauses).
    pub fn quiet() -> StormSpec {
        StormSpec::default()
    }

    /// Parse the clause grammar (see module docs).
    pub fn parse(spec: &str) -> Result<StormSpec> {
        let mut out = StormSpec::default();
        let mut saw_mix = false;
        let mut clauses: Vec<(String, Vec<(String, String)>)> = Vec::new();
        for tok in spec.split(',') {
            let tok = tok.trim();
            if tok.is_empty() {
                continue;
            }
            if let Some((name, first)) = tok.split_once(':') {
                clauses.push((name.to_string(), vec![kv(first)?]));
            } else if tok.contains('=') {
                match clauses.last_mut() {
                    Some((_, params)) => params.push(kv(tok)?),
                    None => {
                        return Err(Error::Config(format!(
                            "storm spec param '{tok}' precedes any clause"
                        )))
                    }
                }
            } else {
                clauses.push((tok.to_string(), Vec::new()));
            }
        }
        for (name, params) in clauses {
            let get_f = |k: &str, d: f64| -> Result<f64> { param_f64(&params, k, d) };
            let get_u = |k: &str, d: u64| -> Result<u64> { param_u64(&params, k, d) };
            match name.as_str() {
                "diurnal" => {
                    let period_s = get_f("period_s", 10.0)?;
                    if period_s <= 0.0 {
                        return Err(Error::Config("diurnal period_s must be > 0".into()));
                    }
                    out.diurnal =
                        Some(Diurnal { period_s, amp: get_f("amp", 0.5)?.clamp(0.0, 1.0) });
                }
                "flash" => {
                    let tenant = TenantId(get_u("tenant", 0)?.min(u8::MAX as u64) as u8);
                    out.flashes.push(Flash {
                        tenant,
                        at_s: get_f("at_s", 0.0)?,
                        for_s: get_f("for_s", 1.0)?,
                        x: get_f("x", 8.0)?.max(1.0),
                        hot: get_u("hot", 0)? as usize,
                    });
                    // a flash on a tenant implies that tenant sends traffic
                    if out.weights[tenant.index()] == 0.0 {
                        out.weights[tenant.index()] = 1.0;
                    }
                }
                "invalidate" => out.invalidations.push(Invalidate {
                    rate: get_f("rate", 100.0)?.max(0.0),
                    at_s: get_f("at_s", 0.0)?,
                    for_s: get_f("for_s", 1.0)?,
                }),
                "mix" => {
                    let mut weights = [0.0; MAX_TENANTS];
                    for (k, v) in &params {
                        let idx: usize = k
                            .strip_prefix('w')
                            .and_then(|d| d.parse().ok())
                            .filter(|&i| i < MAX_TENANTS)
                            .ok_or_else(|| {
                                Error::Config(format!(
                                    "mix param '{k}' is not w0..w{}",
                                    MAX_TENANTS - 1
                                ))
                            })?;
                        weights[idx] = v.parse::<f64>().map_err(|_| {
                            Error::Config(format!("mix weight {k}='{v}' is not a number"))
                        })?;
                    }
                    if weights.iter().all(|&w| w <= 0.0) {
                        return Err(Error::Config("mix has no positive weight".into()));
                    }
                    out.weights = weights;
                    saw_mix = true;
                }
                o => return Err(Error::Config(format!("unknown storm clause '{o}'"))),
            }
        }
        // flashes seen before an explicit mix already defaulted their
        // tenant's weight; an explicit mix wins, but must cover them
        if saw_mix {
            for f in &out.flashes {
                if out.weights[f.tenant.index()] <= 0.0 {
                    return Err(Error::Config(format!(
                        "flash targets tenant {} but mix gives it zero weight",
                        f.tenant.0
                    )));
                }
            }
        }
        Ok(out)
    }

    /// Instantaneous rate multiplier for `tenant` at offset `t_s`,
    /// relative to the tenant's share of the base rate.
    pub fn rate_multiplier(&self, tenant: TenantId, t_s: f64) -> f64 {
        let mut m = match self.diurnal {
            Some(d) => 1.0 + d.amp * (2.0 * std::f64::consts::PI * t_s / d.period_s).sin(),
            None => 1.0,
        };
        for f in &self.flashes {
            if f.tenant == tenant && t_s >= f.at_s && t_s < f.at_s + f.for_s {
                m *= f.x;
            }
        }
        m
    }

    /// The flash window (if any) covering `tenant` at `t_s` that pins a
    /// hot candidate set.
    fn hot_flash(&self, tenant: TenantId, t_s: f64) -> Option<&Flash> {
        self.flashes.iter().find(|f| {
            f.tenant == tenant && f.hot > 0 && t_s >= f.at_s && t_s < f.at_s + f.for_s
        })
    }

    /// Worst-case rate multiplier for `tenant` over the whole run —
    /// the thinning envelope.
    fn peak_multiplier(&self, tenant: TenantId) -> f64 {
        let diurnal = 1.0 + self.diurnal.map_or(0.0, |d| d.amp);
        let flash: f64 = self
            .flashes
            .iter()
            .filter(|f| f.tenant == tenant)
            .map(|f| f.x)
            .fold(1.0, f64::max);
        diurnal * flash
    }

    /// Expand the scenario into a sorted, replayable event timeline.
    /// `base_rate` is the aggregate arrival rate (req/s) split across
    /// tenants by weight; the expansion is deterministic given
    /// `(self, gen's config, base_rate, duration_s, seed)`.
    pub fn generate(
        &self,
        gen: &mut Generator,
        base_rate: f64,
        duration_s: f64,
        seed: u64,
    ) -> Vec<TraceEvent> {
        let total_w: f64 = self.weights.iter().sum();
        let mut rng = Rng::new(seed ^ 0x5702_13AD_57ED_0001);
        let mut events: Vec<TraceEvent> = Vec::new();
        for t in 0..MAX_TENANTS {
            if self.weights[t] <= 0.0 {
                continue;
            }
            let tenant = TenantId(t as u8);
            let tenant_rate = base_rate * self.weights[t] / total_w;
            let peak = tenant_rate * self.peak_multiplier(tenant);
            if peak <= 0.0 {
                continue;
            }
            let mut trng = rng.fork(0x7E00 + t as u64);
            let mut t_s = 0.0_f64;
            loop {
                t_s += trng.exp(peak);
                if t_s >= duration_s {
                    break;
                }
                // thinning: accept with prob rate(t)/peak
                let rate = tenant_rate * self.rate_multiplier(tenant, t_s);
                if trng.next_f64() * peak > rate {
                    continue;
                }
                let mut req = gen.next_request();
                req.tenant = tenant;
                if let Some(f) = self.hot_flash(tenant, t_s) {
                    concentrate(gen, &mut trng, &mut req, f.hot);
                }
                events.push(TraceEvent::Arrival { at_us: (t_s * 1e6) as u64, req });
            }
        }
        events.sort_by_key(|e| e.at_us());
        // invalidation storms target users already seen at that point in
        // the stream, so replays actually evict warm cache entries
        let mut irng = rng.fork(0x1BAD);
        let mut inv: Vec<TraceEvent> = Vec::new();
        for spec in &self.invalidations {
            if spec.rate <= 0.0 {
                continue;
            }
            let mut t_s = spec.at_s;
            loop {
                t_s += irng.exp(spec.rate);
                if t_s >= spec.at_s + spec.for_s || t_s >= duration_s {
                    break;
                }
                let at_us = (t_s * 1e6) as u64;
                let seen = events.partition_point(|e| e.at_us() <= at_us);
                let user_id = if seen == 0 {
                    gen.users().sample_user(&mut irng)
                } else {
                    match &events[irng.below(seen as u64) as usize] {
                        TraceEvent::Arrival { req, .. } => req.user_id,
                        TraceEvent::InvalidateUser { user_id, .. } => *user_id,
                    }
                };
                inv.push(TraceEvent::InvalidateUser { at_us, user_id });
            }
        }
        events.extend(inv);
        events.sort_by_key(|e| e.at_us());
        events
    }
}

/// Redirect a request's candidates onto the `hot` hottest catalog items
/// (rank order — the Zipf head), modelling a flash crowd piling onto the
/// same trending content.
fn concentrate(gen: &Generator, rng: &mut Rng, req: &mut Request, hot: usize) {
    let catalog = gen.catalog();
    let m = req.candidates.len();
    for c in req.candidates.iter_mut() {
        *c = catalog.id_of_rank(rng.below(hot.max(1) as u64));
    }
    debug_assert_eq!(req.candidates.len(), m);
}

fn kv(tok: &str) -> Result<(String, String)> {
    match tok.split_once('=') {
        Some((k, v)) if !k.is_empty() && !v.is_empty() => {
            Ok((k.trim().to_string(), v.trim().to_string()))
        }
        _ => Err(Error::Config(format!("storm spec token '{tok}' is not key=value"))),
    }
}

fn param_f64(params: &[(String, String)], key: &str, default: f64) -> Result<f64> {
    match params.iter().find(|(k, _)| k == key) {
        None => Ok(default),
        Some((_, v)) => v
            .parse::<f64>()
            .map_err(|_| Error::Config(format!("storm param {key}='{v}' is not a number"))),
    }
}

fn param_u64(params: &[(String, String)], key: &str, default: u64) -> Result<u64> {
    match params.iter().find(|(k, _)| k == key) {
        None => Ok(default),
        Some((_, v)) => v
            .parse::<u64>()
            .map_err(|_| Error::Config(format!("storm param {key}='{v}' is not an integer"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkloadConfig;

    fn wl() -> WorkloadConfig {
        WorkloadConfig {
            catalog_size: 10_000,
            zipf_theta: 0.99,
            n_users: 1_000,
            candidate_mix: vec![(16, 1.0)],
            arrival_rate: None,
            seed: 7,
        }
    }

    #[test]
    fn parse_full_grammar() {
        let s = StormSpec::parse(
            "diurnal:period_s=10,amp=0.5,flash:tenant=1,at_s=2,for_s=1,x=8,hot=64,\
             invalidate:rate=500,at_s=2,for_s=1,mix:w0=3,w1=1",
        )
        .unwrap();
        assert_eq!(s.diurnal, Some(Diurnal { period_s: 10.0, amp: 0.5 }));
        assert_eq!(s.flashes.len(), 1);
        let f = s.flashes[0];
        assert_eq!((f.tenant, f.at_s, f.for_s, f.x, f.hot), (TenantId(1), 2.0, 1.0, 8.0, 64));
        assert_eq!(s.invalidations.len(), 1);
        assert_eq!(s.weights[0], 3.0);
        assert_eq!(s.weights[1], 1.0);
        assert!(s.weights[2..].iter().all(|&w| w == 0.0));
    }

    #[test]
    fn parse_rejects_junk() {
        assert!(StormSpec::parse("tsunami:height=3").is_err());
        assert!(StormSpec::parse("amp=0.5").is_err(), "param before any clause");
        assert!(StormSpec::parse("mix:w9=1").is_err(), "tenant out of range");
        assert!(StormSpec::parse("mix:w0=0").is_err(), "all-zero mix");
        assert!(
            StormSpec::parse("flash:tenant=2,mix:w0=1").is_err(),
            "mix must cover flash tenants"
        );
        assert!(StormSpec::parse("diurnal:period_s=0").is_err());
    }

    #[test]
    fn flash_implies_tenant_weight() {
        let s = StormSpec::parse("flash:tenant=1,x=4").unwrap();
        assert!(s.weights[0] > 0.0 && s.weights[1] > 0.0);
    }

    #[test]
    fn rate_multiplier_composes() {
        let s = StormSpec::parse("diurnal:period_s=4,amp=0.5,flash:tenant=1,at_s=0,for_s=4,x=8")
            .unwrap();
        // diurnal peak at t=1 (sin = 1): tenant 0 sees 1.5, tenant 1 sees 12
        assert!((s.rate_multiplier(TenantId(0), 1.0) - 1.5).abs() < 1e-9);
        assert!((s.rate_multiplier(TenantId(1), 1.0) - 12.0).abs() < 1e-9);
        // outside the flash window the multiplier falls back to diurnal
        assert!((s.rate_multiplier(TenantId(1), 5.0) - s.rate_multiplier(TenantId(0), 5.0)).abs()
            < 1e-9);
    }

    #[test]
    fn generate_is_deterministic() {
        let s = StormSpec::parse(
            "diurnal:period_s=2,amp=0.8,flash:tenant=1,at_s=0.5,for_s=0.5,x=6,hot=32,\
             invalidate:rate=200,at_s=0.5,for_s=0.5",
        )
        .unwrap();
        let a = s.generate(&mut Generator::new(&wl(), 16), 2_000.0, 2.0, 42);
        let b = s.generate(&mut Generator::new(&wl(), 16), 2_000.0, 2.0, 42);
        assert!(!a.is_empty());
        assert_eq!(a, b);
        let c = s.generate(&mut Generator::new(&wl(), 16), 2_000.0, 2.0, 43);
        assert_ne!(a, c, "seed changes the timeline");
        assert!(a.windows(2).all(|w| w[0].at_us() <= w[1].at_us()), "sorted by time");
    }

    #[test]
    fn diurnal_shapes_arrivals() {
        // one full period over the run: first half (sin > 0) must carry
        // more arrivals than the second half (sin < 0)
        let s = StormSpec::parse("diurnal:period_s=2,amp=0.9").unwrap();
        let events = s.generate(&mut Generator::new(&wl(), 16), 3_000.0, 2.0, 1);
        let half = events.partition_point(|e| e.at_us() < 1_000_000);
        let (peak, trough) = (half, events.len() - half);
        assert!(
            peak as f64 > 1.5 * trough as f64,
            "diurnal skew: peak={peak} trough={trough}"
        );
    }

    #[test]
    fn flash_concentrates_tenant_and_candidates() {
        let s = StormSpec::parse("flash:tenant=1,at_s=1,for_s=1,x=10,hot=8,mix:w0=1,w1=1")
            .unwrap();
        let events = s.generate(&mut Generator::new(&wl(), 16), 1_000.0, 3.0, 9);
        let mut in_window = [0usize; 2];
        let mut outside = [0usize; 2];
        let mut hot_ids = std::collections::HashSet::new();
        for e in &events {
            if let TraceEvent::Arrival { at_us, req } = e {
                let t = req.tenant.index().min(1);
                if (1_000_000..2_000_000).contains(at_us) {
                    in_window[t] += 1;
                    if req.tenant == TenantId(1) {
                        hot_ids.extend(req.candidates.iter().copied());
                    }
                } else {
                    outside[t] += 1;
                }
            }
        }
        // the storm multiplies tenant 1 only: its in-window rate is ~10x
        // its out-of-window rate (window is 1s of 3s total)
        assert!(
            in_window[1] > 2 * outside[1],
            "flash rate: in={} out={}",
            in_window[1],
            outside[1]
        );
        // tenant 0 is flat: roughly 1/3 of its arrivals in the window
        assert!(
            (in_window[0] as f64) < 0.6 * outside[0] as f64,
            "quiet tenant unperturbed: in={} out={}",
            in_window[0],
            outside[0]
        );
        // flash candidates collapse onto the hot set
        assert!(
            hot_ids.len() <= 8,
            "flash draws from 8 hot items, saw {} distinct",
            hot_ids.len()
        );
    }

    #[test]
    fn invalidations_land_in_window_on_seen_users() {
        let s = StormSpec::parse("invalidate:rate=400,at_s=1,for_s=1").unwrap();
        let events = s.generate(&mut Generator::new(&wl(), 16), 1_000.0, 3.0, 5);
        let mut seen = std::collections::HashSet::new();
        let mut n_inv = 0usize;
        for e in &events {
            match e {
                TraceEvent::Arrival { req, .. } => {
                    seen.insert(req.user_id);
                }
                TraceEvent::InvalidateUser { at_us, user_id } => {
                    n_inv += 1;
                    assert!((1_000_000..2_000_000).contains(at_us), "at_us={at_us}");
                    assert!(seen.contains(user_id), "invalidation hits an already-seen user");
                }
            }
        }
        assert!((200..800).contains(&n_inv), "~400 expected, saw {n_inv}");
    }

    #[test]
    fn timeline_roundtrips_through_trace_layer() {
        use super::super::trace;
        let s = StormSpec::parse(
            "flash:tenant=1,at_s=0.2,for_s=0.3,x=6,hot=16,\
             invalidate:rate=100,at_s=0.2,for_s=0.3",
        )
        .unwrap();
        let events = s.generate(&mut Generator::new(&wl(), 16), 2_000.0, 1.0, 11);
        let path = std::env::temp_dir()
            .join(format!("flame_storm_rt_{}.jsonl", std::process::id()));
        let header = trace::TraceHeader {
            storm: Some("flash:tenant=1".into()),
            base_rate: Some(2_000.0),
            ..trace::TraceHeader::v2()
        };
        trace::record_events(&path, &header, &events).unwrap();
        let (h, back) = trace::replay_events(&path).unwrap();
        assert_eq!(h, header);
        assert_eq!(back, events, "every arm replays the identical storm");
        std::fs::remove_file(&path).unwrap();
    }
}
