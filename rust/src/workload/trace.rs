//! JSONL trace record/replay: capture a generated workload to a file and
//! replay the exact request stream later (cross-run comparability for the
//! ablation tables; also the "bypass stream of real online traffic"
//! stand-in — a recorded trace replays identically against every arm).

use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

use crate::error::{io_err, Result};
use crate::util::json::{parse, Json};

use super::Request;

/// Serialize one request as a JSONL line.
pub fn request_to_line(r: &Request) -> String {
    let j = Json::obj(vec![
        ("id", Json::num(r.request_id as f64)),
        ("user", Json::num(r.user_id as f64)),
        (
            "history",
            Json::Arr(r.history.iter().map(|&i| Json::num(i as f64)).collect()),
        ),
        (
            "candidates",
            Json::Arr(r.candidates.iter().map(|&i| Json::num(i as f64)).collect()),
        ),
    ]);
    j.to_string()
}

/// Parse one JSONL line back into a request.
pub fn request_from_line(line: &str) -> Result<Request> {
    let j = parse(line)?;
    let ids = |key: &str| -> Result<Vec<u64>> {
        j.get(key)?.as_arr()?.iter().map(|v| v.as_u64()).collect()
    };
    Ok(Request {
        request_id: j.get("id")?.as_u64()?,
        user_id: j.get("user")?.as_u64()?,
        history: ids("history")?,
        candidates: ids("candidates")?,
    })
}

/// Write a trace file.
pub fn record(path: &Path, requests: &[Request]) -> Result<()> {
    let f = std::fs::File::create(path).map_err(io_err(path.display().to_string()))?;
    let mut w = BufWriter::new(f);
    for r in requests {
        writeln!(w, "{}", request_to_line(r)).map_err(io_err(path.display().to_string()))?;
    }
    w.flush().map_err(io_err(path.display().to_string()))?;
    Ok(())
}

/// Read a trace file.
pub fn replay(path: &Path) -> Result<Vec<Request>> {
    let f = std::fs::File::open(path).map_err(io_err(path.display().to_string()))?;
    let reader = std::io::BufReader::new(f);
    let mut out = Vec::new();
    for (i, line) in reader.lines().enumerate() {
        let line = line.map_err(io_err(path.display().to_string()))?;
        if line.trim().is_empty() {
            continue;
        }
        out.push(request_from_line(&line).map_err(|e| {
            crate::error::Error::Json(format!("{}:{}: {e}", path.display(), i + 1))
        })?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<Request> {
        vec![
            Request { request_id: 0, user_id: 5, history: vec![1, 2, 3], candidates: vec![9, 8] },
            Request { request_id: 1, user_id: 6, history: vec![4], candidates: vec![7] },
        ]
    }

    #[test]
    fn line_roundtrip() {
        for r in sample() {
            let line = request_to_line(&r);
            assert_eq!(request_from_line(&line).unwrap(), r);
        }
    }

    #[test]
    fn file_roundtrip() {
        let path = std::env::temp_dir().join(format!("flame_trace_{}.jsonl", std::process::id()));
        let reqs = sample();
        record(&path, &reqs).unwrap();
        let back = replay(&path).unwrap();
        assert_eq!(back, reqs);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn replay_reports_bad_line_number() {
        let path = std::env::temp_dir().join(format!("flame_badtrace_{}.jsonl", std::process::id()));
        std::fs::write(&path, "{\"id\": 0, \"user\": 1, \"history\": [], \"candidates\": []}\nnot json\n").unwrap();
        let err = replay(&path).unwrap_err().to_string();
        assert!(err.contains(":2:"), "{err}");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn blank_lines_skipped() {
        let path = std::env::temp_dir().join(format!("flame_blank_{}.jsonl", std::process::id()));
        std::fs::write(
            &path,
            "\n{\"id\": 3, \"user\": 1, \"history\": [2], \"candidates\": [4]}\n\n",
        )
        .unwrap();
        let reqs = replay(&path).unwrap();
        assert_eq!(reqs.len(), 1);
        assert_eq!(reqs[0].request_id, 3);
        std::fs::remove_file(&path).unwrap();
    }
}
