//! JSONL trace record/replay: capture a generated workload to a file and
//! replay the exact request stream later (cross-run comparability for the
//! ablation tables; also the "bypass stream of real online traffic"
//! stand-in — a recorded trace replays identically against every arm).
//!
//! # Format (version 2)
//!
//! The first line of a v2 trace is a header object carrying the version
//! plus optional provenance (`scenario`, the `storm` spec the stream was
//! generated from, the base arrival rate):
//!
//! ```text
//! {"flame_trace": 2, "storm": "flash:tenant=1,x=8", "base_rate": 2000}
//! {"id": 0, "user": 17, "history": [..], "candidates": [..], "tenant": 1, "at_us": 512}
//! {"event": "invalidate_user", "user": 17, "at_us": 90000}
//! ```
//!
//! Request lines gained two optional fields — `tenant` (omitted when 0)
//! and `at_us` (arrival offset from stream start, omitted when 0) — and
//! the stream may now interleave *event* lines (feature-update
//! invalidations driving `ClusterRouter::invalidate_user` at replay
//! time). **Forward compatibility is a contract both ways**: headerless
//! v1 traces still replay (every line a request, tenant 0, arrival order
//! = file order), and unknown event kinds from future versions are
//! skipped, not fatal — `tests` pin both behaviors.

use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

use crate::error::{io_err, Result};
use crate::util::json::{parse, Json};

use super::{Request, TenantId};

/// Trace format version written by [`record`] / [`record_events`].
pub const TRACE_VERSION: u64 = 2;

/// Parsed trace header. Headerless (v1) files get `version: 1` and no
/// provenance.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TraceHeader {
    pub version: u64,
    /// Scenario the trace was generated for (informational).
    pub scenario: Option<String>,
    /// Storm spec (see `workload::storm`) the stream was generated from.
    pub storm: Option<String>,
    /// Base arrival rate (req/s) the at_us offsets were generated at.
    pub base_rate: Option<f64>,
}

impl TraceHeader {
    pub fn v2() -> Self {
        TraceHeader { version: TRACE_VERSION, ..TraceHeader::default() }
    }
}

/// One timeline entry of a v2 trace.
#[derive(Clone, Debug, PartialEq)]
pub enum TraceEvent {
    /// A request arriving `at_us` after stream start.
    Arrival { at_us: u64, req: Request },
    /// A feature update for `user_id` — replay drives
    /// `ClusterRouter::invalidate_user` so cached results for the user
    /// cannot outlive the update.
    InvalidateUser { at_us: u64, user_id: u64 },
}

impl TraceEvent {
    pub fn at_us(&self) -> u64 {
        match self {
            TraceEvent::Arrival { at_us, .. } => *at_us,
            TraceEvent::InvalidateUser { at_us, .. } => *at_us,
        }
    }
}

/// Serialize one request as a JSONL line (no arrival offset — see
/// [`event_to_line`] for the timed form). `tenant` is emitted only when
/// nonzero, so single-tenant traces are byte-identical to v1 lines.
pub fn request_to_line(r: &Request) -> String {
    let mut fields = vec![
        ("id", Json::num(r.request_id as f64)),
        ("user", Json::num(r.user_id as f64)),
        (
            "history",
            Json::Arr(r.history.iter().map(|&i| Json::num(i as f64)).collect()),
        ),
        (
            "candidates",
            Json::Arr(r.candidates.iter().map(|&i| Json::num(i as f64)).collect()),
        ),
    ];
    if r.tenant.0 != 0 {
        fields.push(("tenant", Json::num(r.tenant.0 as f64)));
    }
    Json::obj(fields).to_string()
}

/// Serialize one timeline entry as a JSONL line.
pub fn event_to_line(e: &TraceEvent) -> String {
    match e {
        TraceEvent::Arrival { at_us, req } => {
            if *at_us == 0 {
                return request_to_line(req);
            }
            // splice the offset into the request object
            let line = request_to_line(req);
            let body = line.strip_suffix('}').unwrap_or(&line);
            format!("{body},\"at_us\":{at_us}}}")
        }
        TraceEvent::InvalidateUser { at_us, user_id } => Json::obj(vec![
            ("event", Json::Str("invalidate_user".into())),
            ("user", Json::num(*user_id as f64)),
            ("at_us", Json::num(*at_us as f64)),
        ])
        .to_string(),
    }
}

/// Serialize the header line.
pub fn header_to_line(h: &TraceHeader) -> String {
    let mut fields = vec![("flame_trace", Json::num(h.version as f64))];
    if let Some(s) = &h.scenario {
        fields.push(("scenario", Json::Str(s.clone())));
    }
    if let Some(s) = &h.storm {
        fields.push(("storm", Json::Str(s.clone())));
    }
    if let Some(r) = h.base_rate {
        fields.push(("base_rate", Json::num(r)));
    }
    Json::obj(fields).to_string()
}

/// Parse one JSONL line back into a request. `tenant` and `at_us` are
/// optional (v1 lines lack both).
pub fn request_from_line(line: &str) -> Result<Request> {
    let j = parse(line)?;
    let ids = |key: &str| -> Result<Vec<u64>> {
        j.get(key)?.as_arr()?.iter().map(|v| v.as_u64()).collect()
    };
    let tenant = match j.opt("tenant") {
        Some(v) => TenantId(v.as_u64()?.min(u8::MAX as u64) as u8),
        None => TenantId::default(),
    };
    Ok(Request {
        request_id: j.get("id")?.as_u64()?,
        user_id: j.get("user")?.as_u64()?,
        history: ids("history")?,
        candidates: ids("candidates")?,
        tenant,
    })
}

/// Parse one line as a timeline entry. Returns `Ok(None)` for event
/// kinds this version does not know (forward compatibility: a newer
/// trace replays, minus the events we cannot interpret).
pub fn event_from_line(line: &str) -> Result<Option<TraceEvent>> {
    let j = parse(line)?;
    if let Some(ev) = j.opt("event") {
        return match ev.as_str()? {
            "invalidate_user" => Ok(Some(TraceEvent::InvalidateUser {
                at_us: match j.opt("at_us") {
                    Some(v) => v.as_u64()?,
                    None => 0,
                },
                user_id: j.get("user")?.as_u64()?,
            })),
            _ => Ok(None),
        };
    }
    let at_us = match j.opt("at_us") {
        Some(v) => v.as_u64()?,
        None => 0,
    };
    Ok(Some(TraceEvent::Arrival { at_us, req: request_from_line(line)? }))
}

/// Write a trace file (v2: header line + one request per line, file
/// order = arrival order).
pub fn record(path: &Path, requests: &[Request]) -> Result<()> {
    let events: Vec<TraceEvent> = requests
        .iter()
        .map(|r| TraceEvent::Arrival { at_us: 0, req: r.clone() })
        .collect();
    record_events(path, &TraceHeader::v2(), &events)
}

/// Write a full v2 timeline (header + arrivals + invalidation events).
pub fn record_events(path: &Path, header: &TraceHeader, events: &[TraceEvent]) -> Result<()> {
    let f = std::fs::File::create(path).map_err(io_err(path.display().to_string()))?;
    let mut w = BufWriter::new(f);
    let werr = || io_err(path.display().to_string());
    writeln!(w, "{}", header_to_line(header)).map_err(werr())?;
    for e in events {
        writeln!(w, "{}", event_to_line(e)).map_err(werr())?;
    }
    w.flush().map_err(werr())?;
    Ok(())
}

/// Read a trace file as a plain request stream (events and unknown
/// lines skipped) — the replay surface every pre-tenancy caller uses.
pub fn replay(path: &Path) -> Result<Vec<Request>> {
    let (_, events) = replay_events(path)?;
    Ok(events
        .into_iter()
        .filter_map(|e| match e {
            TraceEvent::Arrival { req, .. } => Some(req),
            TraceEvent::InvalidateUser { .. } => None,
        })
        .collect())
}

/// Read a trace file as a full timeline. A v1 (headerless) file parses
/// as `version: 1` with every line an `at_us: 0` arrival in file order.
pub fn replay_events(path: &Path) -> Result<(TraceHeader, Vec<TraceEvent>)> {
    let f = std::fs::File::open(path).map_err(io_err(path.display().to_string()))?;
    let reader = std::io::BufReader::new(f);
    let mut header = TraceHeader { version: 1, ..TraceHeader::default() };
    let mut saw_line = false;
    let mut out = Vec::new();
    for (i, line) in reader.lines().enumerate() {
        let line = line.map_err(io_err(path.display().to_string()))?;
        if line.trim().is_empty() {
            continue;
        }
        let at_line = |e: crate::error::Error| {
            crate::error::Error::Json(format!("{}:{}: {e}", path.display(), i + 1))
        };
        if !saw_line {
            saw_line = true;
            let j = parse(&line).map_err(at_line)?;
            if let Some(v) = j.opt("flame_trace") {
                header.version = v.as_u64().map_err(at_line)?;
                if let Some(s) = j.opt("scenario") {
                    header.scenario = Some(s.as_str().map_err(at_line)?.to_string());
                }
                if let Some(s) = j.opt("storm") {
                    header.storm = Some(s.as_str().map_err(at_line)?.to_string());
                }
                if let Some(r) = j.opt("base_rate") {
                    header.base_rate = Some(r.as_f64().map_err(at_line)?);
                }
                continue;
            }
        }
        if let Some(e) = event_from_line(&line).map_err(at_line)? {
            out.push(e);
        }
    }
    Ok((header, out))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<Request> {
        vec![
            Request {
                request_id: 0,
                user_id: 5,
                history: vec![1, 2, 3],
                candidates: vec![9, 8],
                ..Default::default()
            },
            Request {
                request_id: 1,
                user_id: 6,
                history: vec![4],
                candidates: vec![7],
                tenant: TenantId(2),
            },
        ]
    }

    fn tmp(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("flame_{tag}_{}.jsonl", std::process::id()))
    }

    #[test]
    fn line_roundtrip() {
        for r in sample() {
            let line = request_to_line(&r);
            assert_eq!(request_from_line(&line).unwrap(), r);
        }
    }

    #[test]
    fn tenant_zero_line_is_v1_shaped() {
        // single-tenant request lines carry no tenant field at all
        let line = request_to_line(&sample()[0]);
        assert!(!line.contains("tenant"), "{line}");
    }

    #[test]
    fn file_roundtrip() {
        let path = tmp("trace");
        let reqs = sample();
        record(&path, &reqs).unwrap();
        let back = replay(&path).unwrap();
        assert_eq!(back, reqs, "tenant ids survive the round trip");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn v1_headerless_trace_still_replays() {
        // the forward-compat contract: a pre-header trace (every line a
        // request, no tenant/at_us fields) parses as version 1, tenant 0
        let path = tmp("v1");
        std::fs::write(
            &path,
            "{\"id\": 0, \"user\": 1, \"history\": [2], \"candidates\": [3]}\n\
             {\"id\": 1, \"user\": 4, \"history\": [], \"candidates\": [5, 6]}\n",
        )
        .unwrap();
        let (header, events) = replay_events(&path).unwrap();
        assert_eq!(header.version, 1);
        assert_eq!(events.len(), 2);
        let reqs = replay(&path).unwrap();
        assert_eq!(reqs.len(), 2);
        assert!(reqs.iter().all(|r| r.tenant == TenantId(0)));
        assert_eq!(reqs[1].candidates, vec![5, 6]);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn event_timeline_roundtrip() {
        let path = tmp("events");
        let header = TraceHeader {
            version: TRACE_VERSION,
            scenario: Some("sim".into()),
            storm: Some("flash:tenant=1,x=8".into()),
            base_rate: Some(2_000.0),
        };
        let events = vec![
            TraceEvent::Arrival { at_us: 0, req: sample()[0].clone() },
            TraceEvent::InvalidateUser { at_us: 500, user_id: 5 },
            TraceEvent::Arrival { at_us: 900, req: sample()[1].clone() },
        ];
        record_events(&path, &header, &events).unwrap();
        let (h, back) = replay_events(&path).unwrap();
        assert_eq!(h, header);
        assert_eq!(back, events);
        // the plain-replay surface sees only the arrivals
        assert_eq!(replay(&path).unwrap(), sample());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn unknown_event_kinds_are_skipped_not_fatal() {
        let path = tmp("future");
        std::fs::write(
            &path,
            "{\"flame_trace\": 3, \"something_new\": true}\n\
             {\"event\": \"rebalance_shards\", \"at_us\": 5}\n\
             {\"id\": 0, \"user\": 1, \"history\": [], \"candidates\": [2], \"tenant\": 1}\n",
        )
        .unwrap();
        let (header, events) = replay_events(&path).unwrap();
        assert_eq!(header.version, 3);
        assert_eq!(events.len(), 1, "unknown event skipped: {events:?}");
        assert_eq!(replay(&path).unwrap()[0].tenant, TenantId(1));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn replay_reports_bad_line_number() {
        let path = tmp("badtrace");
        std::fs::write(&path, "{\"id\": 0, \"user\": 1, \"history\": [], \"candidates\": []}\nnot json\n").unwrap();
        let err = replay(&path).unwrap_err().to_string();
        assert!(err.contains(":2:"), "{err}");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn blank_lines_skipped() {
        let path = tmp("blank");
        std::fs::write(
            &path,
            "\n{\"id\": 3, \"user\": 1, \"history\": [2], \"candidates\": [4]}\n\n",
        )
        .unwrap();
        let reqs = replay(&path).unwrap();
        assert_eq!(reqs.len(), 1);
        assert_eq!(reqs[0].request_id, 3);
        std::fs::remove_file(&path).unwrap();
    }
}
