//! Synthetic workload generation — the production-traffic substitute
//! (DESIGN.md §Environment substitutions): request synthesis over the
//! catalog/user base, candidate-count mixes (Table 5's non-uniform
//! upstream), arrival processes, and JSONL trace record/replay.

pub mod driver;
pub mod trace;

use std::sync::Arc;

use crate::config::WorkloadConfig;
use crate::featurestore::catalog::{Catalog, UserBase};
use crate::util::rng::Rng;

/// One inference request as it arrives from upstream.
#[derive(Clone, Debug, PartialEq)]
pub struct Request {
    pub request_id: u64,
    pub user_id: u64,
    /// The user's interaction history (item ids), already truncated to
    /// the model's L.
    pub history: Vec<u64>,
    /// Candidate item ids from the upstream retriever (len = this
    /// request's M — *not* necessarily a profile size).
    pub candidates: Vec<u64>,
}

impl Request {
    pub fn m(&self) -> usize {
        self.candidates.len()
    }
}

/// Deterministic request generator.
pub struct Generator {
    catalog: Arc<Catalog>,
    users: Arc<UserBase>,
    mix: Vec<(usize, f64)>, // cumulative weights computed on the fly
    mix_total: f64,
    seq_len: usize,
    rng: Rng,
    next_id: u64,
}

impl Generator {
    pub fn new(cfg: &WorkloadConfig, seq_len: usize) -> Self {
        let catalog = Arc::new(Catalog::new(cfg.catalog_size, cfg.zipf_theta));
        let users = Arc::new(UserBase::new(cfg.n_users, cfg.seed ^ 0xA5A5));
        let mix_total = cfg.candidate_mix.iter().map(|&(_, w)| w).sum();
        Generator {
            catalog,
            users,
            mix: cfg.candidate_mix.clone(),
            mix_total,
            seq_len,
            rng: Rng::new(cfg.seed),
            next_id: 0,
        }
    }

    pub fn catalog(&self) -> &Arc<Catalog> {
        &self.catalog
    }

    pub fn users(&self) -> &Arc<UserBase> {
        &self.users
    }

    /// Draw this request's candidate count from the configured mix.
    fn sample_m(&mut self) -> usize {
        if self.mix.len() == 1 {
            return self.mix[0].0;
        }
        let x = self.rng.next_f64() * self.mix_total;
        let mut acc = 0.0;
        for &(m, w) in &self.mix {
            acc += w;
            if x < acc {
                return m;
            }
        }
        self.mix.last().unwrap().0
    }

    /// Generate the next request.
    pub fn next_request(&mut self) -> Request {
        let user_id = self.users.sample_user(&mut self.rng);
        let m = self.sample_m();
        let history = self.users.history(&self.catalog, user_id, self.seq_len);
        let candidates = self.catalog.sample_candidates(&mut self.rng, m);
        let request_id = self.next_id;
        self.next_id += 1;
        Request { request_id, user_id, history, candidates }
    }

    /// Generate a batch of n requests.
    pub fn batch(&mut self, n: usize) -> Vec<Request> {
        (0..n).map(|_| self.next_request()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(mix: Vec<(usize, f64)>) -> WorkloadConfig {
        WorkloadConfig {
            catalog_size: 10_000,
            zipf_theta: 0.99,
            n_users: 1_000,
            candidate_mix: mix,
            arrival_rate: None,
            seed: 7,
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Generator::new(&cfg(vec![(8, 1.0)]), 32);
        let mut b = Generator::new(&cfg(vec![(8, 1.0)]), 32);
        for _ in 0..10 {
            assert_eq!(a.next_request(), b.next_request());
        }
    }

    #[test]
    fn shapes_respected() {
        let mut g = Generator::new(&cfg(vec![(8, 1.0)]), 32);
        let r = g.next_request();
        assert_eq!(r.history.len(), 32);
        assert_eq!(r.m(), 8);
        assert!(r.user_id < 1_000);
    }

    #[test]
    fn mix_distribution_roughly_uniform() {
        let mix = vec![(128, 1.0), (256, 1.0), (512, 1.0), (1024, 1.0)];
        let mut g = Generator::new(&cfg(mix), 32);
        let mut counts = std::collections::HashMap::new();
        for _ in 0..4000 {
            *counts.entry(g.next_request().m()).or_insert(0usize) += 1;
        }
        for m in [128usize, 256, 512, 1024] {
            let c = counts[&m];
            assert!((700..1300).contains(&c), "m={m} count={c}");
        }
    }

    #[test]
    fn request_ids_monotone() {
        let mut g = Generator::new(&cfg(vec![(4, 1.0)]), 16);
        let ids: Vec<u64> = g.batch(5).iter().map(|r| r.request_id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn hot_items_recur_across_requests() {
        let mut g = Generator::new(&cfg(vec![(32, 1.0)]), 32);
        let mut seen = std::collections::HashMap::new();
        for _ in 0..200 {
            for id in g.next_request().candidates {
                *seen.entry(id).or_insert(0usize) += 1;
            }
        }
        let max_repeat = seen.values().copied().max().unwrap();
        assert!(max_repeat > 10, "Zipf head item repeated {max_repeat} times");
    }
}
