//! Synthetic workload generation — the production-traffic substitute
//! (DESIGN.md §Environment substitutions): request synthesis over the
//! catalog/user base, candidate-count mixes (Table 5's non-uniform
//! upstream), arrival processes, and JSONL trace record/replay.

pub mod driver;
pub mod storm;
pub mod trace;

use std::sync::Arc;

use crate::config::WorkloadConfig;
use crate::error::{Error, Result};
use crate::featurestore::catalog::{Catalog, UserBase};
use crate::util::rng::Rng;

/// Candidate-count (M) distribution families over a profile set — the
/// paper's "non-uniform distribution of upstream candidates" is where
/// the DSO (and its batch coalescer) wins most, so benches and the
/// trace generator can reproduce it with one knob (`--m-dist`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MDist {
    /// Equal weight over the whole [`MDist::support`] (profiles *and*
    /// off-profile values). Note this is a fair same-support baseline
    /// for the bimodal/zipf arms, not Table 5's profiles-only mix —
    /// that one is `WorkloadConfig::uniform_mix`.
    Uniform,
    /// Mass at both ends: mostly tiny requests plus a heavy large tail,
    /// the skew that leaves many near-empty remainder launches.
    Bimodal,
    /// Zipf-decaying weight over ascending M: most requests small.
    Zipf,
}

impl MDist {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "uniform" => Ok(MDist::Uniform),
            "bimodal" => Ok(MDist::Bimodal),
            "zipf" => Ok(MDist::Zipf),
            o => Err(Error::Config(format!("unknown m-dist '{o}' (uniform|bimodal|zipf)"))),
        }
    }

    /// The M values a distribution draws from. Upstream retrievers do
    /// not know the engine profile set, so alongside each profile size
    /// the support includes off-profile values — a tiny M below the
    /// smallest profile (the 1-candidate pathology) and midpoints
    /// between consecutive profiles — which is what produces the
    /// remainder chunks the batch coalescer packs.
    pub fn support(profiles: &[usize]) -> Vec<usize> {
        let mut ps = profiles.to_vec();
        ps.sort_unstable();
        ps.dedup();
        let mut vals = Vec::new();
        if let Some(&lo) = ps.first() {
            if lo > 1 {
                vals.push((lo / 4).max(1));
            }
        }
        for w in ps.windows(2) {
            vals.push(w[0]);
            vals.push((w[0] + w[1]) / 2);
        }
        if let Some(&hi) = ps.last() {
            vals.push(hi);
        }
        vals.sort_unstable();
        vals.dedup();
        vals
    }

    /// Weighted candidate mix over [`MDist::support`]`(profiles)` for
    /// `WorkloadConfig::candidate_mix`.
    pub fn mix(&self, profiles: &[usize]) -> Vec<(usize, f64)> {
        let vals = Self::support(profiles);
        let n = vals.len();
        match self {
            MDist::Uniform => vals.into_iter().map(|m| (m, 1.0)).collect(),
            MDist::Bimodal => match n {
                0 => Vec::new(),
                1 => vec![(vals[0], 1.0)],
                2 => vec![(vals[0], 0.5), (vals[1], 0.5)],
                _ => {
                    let mid = 0.10 / (n - 2) as f64;
                    vals.into_iter()
                        .enumerate()
                        .map(|(i, m)| {
                            let w = if i == 0 || i == n - 1 { 0.45 } else { mid };
                            (m, w)
                        })
                        .collect()
                }
            },
            MDist::Zipf => vals
                .into_iter()
                .enumerate()
                .map(|(i, m)| (m, 1.0 / ((i + 1) as f64).powf(1.2)))
                .collect(),
        }
    }
}

/// Upper bound on distinct tenants sharing one cluster. Fixed at
/// compile time so every per-tenant hot-path structure (admission
/// controller state, recorder views) is a flat array — the controller
/// tick and the per-request accounting stay allocation-free.
pub const MAX_TENANTS: usize = 8;

/// Which tenant (scenario / product surface) a request belongs to.
/// Tenant 0 is the implicit default for single-tenant traffic, so every
/// pre-tenancy trace, test, and caller keeps its old behavior.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TenantId(pub u8);

impl TenantId {
    /// Flat-array slot for this tenant. Ids at or beyond [`MAX_TENANTS`]
    /// fold into the last slot instead of panicking — a hostile or
    /// corrupt tenant id must never take down an accounting path.
    pub fn index(self) -> usize {
        (self.0 as usize).min(MAX_TENANTS - 1)
    }
}

/// One inference request as it arrives from upstream.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Request {
    pub request_id: u64,
    pub user_id: u64,
    /// The user's interaction history (item ids), already truncated to
    /// the model's L.
    pub history: Vec<u64>,
    /// Candidate item ids from the upstream retriever (len = this
    /// request's M — *not* necessarily a profile size).
    pub candidates: Vec<u64>,
    /// Owning tenant; drives per-tenant SLA budgets, admission feedback,
    /// and recorder views. Defaults to tenant 0.
    pub tenant: TenantId,
}

impl Request {
    pub fn m(&self) -> usize {
        self.candidates.len()
    }
}

/// Deterministic request generator.
pub struct Generator {
    catalog: Arc<Catalog>,
    users: Arc<UserBase>,
    mix: Vec<(usize, f64)>, // cumulative weights computed on the fly
    mix_total: f64,
    seq_len: usize,
    rng: Rng,
    next_id: u64,
}

impl Generator {
    pub fn new(cfg: &WorkloadConfig, seq_len: usize) -> Self {
        let catalog = Arc::new(Catalog::new(cfg.catalog_size, cfg.zipf_theta));
        let users = Arc::new(UserBase::new(cfg.n_users, cfg.seed ^ 0xA5A5));
        let mix_total = cfg.candidate_mix.iter().map(|&(_, w)| w).sum();
        Generator {
            catalog,
            users,
            mix: cfg.candidate_mix.clone(),
            mix_total,
            seq_len,
            rng: Rng::new(cfg.seed),
            next_id: 0,
        }
    }

    pub fn catalog(&self) -> &Arc<Catalog> {
        &self.catalog
    }

    pub fn users(&self) -> &Arc<UserBase> {
        &self.users
    }

    /// Draw this request's candidate count from the configured mix.
    fn sample_m(&mut self) -> usize {
        if self.mix.len() == 1 {
            return self.mix[0].0;
        }
        let x = self.rng.next_f64() * self.mix_total;
        let mut acc = 0.0;
        for &(m, w) in &self.mix {
            acc += w;
            if x < acc {
                return m;
            }
        }
        self.mix.last().unwrap().0
    }

    /// Generate the next request.
    pub fn next_request(&mut self) -> Request {
        let user_id = self.users.sample_user(&mut self.rng);
        let m = self.sample_m();
        let history = self.users.history(&self.catalog, user_id, self.seq_len);
        let candidates = self.catalog.sample_candidates(&mut self.rng, m);
        let request_id = self.next_id;
        self.next_id += 1;
        Request { request_id, user_id, history, candidates, tenant: TenantId::default() }
    }

    /// Generate a batch of n requests.
    pub fn batch(&mut self, n: usize) -> Vec<Request> {
        (0..n).map(|_| self.next_request()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(mix: Vec<(usize, f64)>) -> WorkloadConfig {
        WorkloadConfig {
            catalog_size: 10_000,
            zipf_theta: 0.99,
            n_users: 1_000,
            candidate_mix: mix,
            arrival_rate: None,
            seed: 7,
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Generator::new(&cfg(vec![(8, 1.0)]), 32);
        let mut b = Generator::new(&cfg(vec![(8, 1.0)]), 32);
        for _ in 0..10 {
            assert_eq!(a.next_request(), b.next_request());
        }
    }

    #[test]
    fn shapes_respected() {
        let mut g = Generator::new(&cfg(vec![(8, 1.0)]), 32);
        let r = g.next_request();
        assert_eq!(r.history.len(), 32);
        assert_eq!(r.m(), 8);
        assert!(r.user_id < 1_000);
    }

    #[test]
    fn mix_distribution_roughly_uniform() {
        let mix = vec![(128, 1.0), (256, 1.0), (512, 1.0), (1024, 1.0)];
        let mut g = Generator::new(&cfg(mix), 32);
        let mut counts = std::collections::HashMap::new();
        for _ in 0..4000 {
            *counts.entry(g.next_request().m()).or_insert(0usize) += 1;
        }
        for m in [128usize, 256, 512, 1024] {
            let c = counts[&m];
            assert!((700..1300).contains(&c), "m={m} count={c}");
        }
    }

    #[test]
    fn tenant_index_defaults_and_folds() {
        assert_eq!(TenantId::default().index(), 0);
        assert_eq!(TenantId(3).index(), 3);
        // corrupt/out-of-range ids fold into the last slot, never panic
        assert_eq!(TenantId(200).index(), MAX_TENANTS - 1);
        assert_eq!(Request::default().tenant, TenantId(0));
    }

    #[test]
    fn request_ids_monotone() {
        let mut g = Generator::new(&cfg(vec![(4, 1.0)]), 16);
        let ids: Vec<u64> = g.batch(5).iter().map(|r| r.request_id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn m_dist_support_includes_off_profile_values() {
        let s = MDist::support(&[128, 256, 512, 1024]);
        // tiny request below the smallest profile
        assert!(s.contains(&32), "{s:?}");
        // midpoints between profiles (remainder-producing)
        assert!(s.contains(&192) && s.contains(&384) && s.contains(&768), "{s:?}");
        // the profiles themselves
        for p in [128, 256, 512, 1024] {
            assert!(s.contains(&p), "{s:?}");
        }
        assert!(s.windows(2).all(|w| w[0] < w[1]), "sorted, deduped: {s:?}");
    }

    #[test]
    fn m_dist_parse_and_families() {
        assert_eq!(MDist::parse("bimodal").unwrap(), MDist::Bimodal);
        assert!(MDist::parse("nope").is_err());
        let profiles = [16usize, 32, 64, 128];
        let uni = MDist::Uniform.mix(&profiles);
        assert!(uni.iter().all(|&(_, w)| w == 1.0));
        let bi = MDist::Bimodal.mix(&profiles);
        let (first, last) = (bi.first().unwrap(), bi.last().unwrap());
        assert!(first.1 > 0.4 && last.1 > 0.4, "mass at both ends: {bi:?}");
        assert!(bi[1..bi.len() - 1].iter().all(|&(_, w)| w < 0.1), "light middle: {bi:?}");
        let zipf = MDist::Zipf.mix(&profiles);
        assert!(
            zipf.windows(2).all(|w| w[0].1 > w[1].1),
            "zipf weight decays with M: {zipf:?}"
        );
    }

    #[test]
    fn m_dist_generator_draws_skewed_m() {
        let mix = MDist::Zipf.mix(&[16, 32, 64, 128]);
        let mut g = Generator::new(&cfg(mix), 32);
        let mut small = 0usize;
        let n = 2_000;
        for _ in 0..n {
            if g.next_request().m() <= 16 {
                small += 1;
            }
        }
        // the two smallest support values carry the bulk of a zipf draw
        assert!(small > n / 3, "zipf skew toward small M, saw {small}/{n}");
    }

    #[test]
    fn hot_items_recur_across_requests() {
        let mut g = Generator::new(&cfg(vec![(32, 1.0)]), 32);
        let mut seen = std::collections::HashMap::new();
        for _ in 0..200 {
            for id in g.next_request().candidates {
                *seen.entry(id).or_insert(0usize) += 1;
            }
        }
        let max_repeat = seen.values().copied().max().unwrap();
        assert!(max_repeat > 10, "Zipf head item repeated {max_repeat} times");
    }
}
