//! TCP front integration: start the server on the tiny stack, drive it
//! with the binary-protocol client, check scores match in-process serving.

use std::sync::Arc;

use flame::config::{CacheMode, StackConfig};
use flame::manifest::testvec::max_abs_diff;
use flame::manifest::Manifest;
use flame::pda::StagingArena;
use flame::runtime::Runtime;
use flame::server::pipeline::StackBuilder;
use flame::server::tcp::{TcpClient, TcpServer};
use flame::workload::Request;

fn stack() -> Option<Arc<flame::server::ServingStack>> {
    let manifest = Manifest::load("artifacts").ok()?;
    if !manifest.scenarios.contains_key("tiny") {
        eprintln!("skipping: artifacts/tiny not built");
        return None;
    }
    let rt = match Runtime::new() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping: PJRT runtime unavailable ({e})");
            return None;
        }
    };
    let mut cfg = StackConfig::default();
    cfg.pda.cache_mode = CacheMode::Sync;
    Some(Arc::new(StackBuilder::new("tiny", "fused", cfg).build(&rt, &manifest).ok()?))
}

fn request(id: u64, m: usize, l: usize) -> Request {
    Request {
        request_id: id,
        user_id: id % 10,
        history: (0..l as u64).map(|i| i * 3 + id).collect(),
        candidates: (0..m as u64).map(|i| 1000 + i * 7 + id).collect(),
        ..Default::default()
    }
}

#[test]
fn tcp_roundtrip_matches_inprocess() {
    let Some(stack) = stack() else { return };
    let server = TcpServer::start(Arc::clone(&stack), "127.0.0.1:0").expect("start");
    let mut client = TcpClient::connect(&server.addr).expect("connect");

    let req = request(1, 8, stack.model_cfg.seq_len);
    let wire = client.call(&req).expect("call");
    assert_eq!(wire.status, 0);
    assert_eq!(wire.request_id, 1);
    assert_eq!(wire.m, 8);
    assert_eq!(wire.n_tasks, stack.model_cfg.n_tasks);

    // in-process reference (features are cached+deterministic, so equal)
    let mut arena = StagingArena::new(1 << 16);
    let direct = stack.serve(&req, &mut arena).expect("direct");
    assert!(max_abs_diff(&wire.scores, &direct.scores) < 1e-6);

    server.shutdown();
}

#[test]
fn tcp_multiple_requests_one_connection() {
    let Some(stack) = stack() else { return };
    let server = TcpServer::start(Arc::clone(&stack), "127.0.0.1:0").expect("start");
    let mut client = TcpClient::connect(&server.addr).expect("connect");
    for id in 0..5u64 {
        let m = if id % 2 == 0 { 4 } else { 8 };
        let wire = client.call(&request(id, m, stack.model_cfg.seq_len)).expect("call");
        assert_eq!(wire.status, 0);
        assert_eq!(wire.request_id, id);
        assert_eq!(wire.scores.len(), m * stack.model_cfg.n_tasks);
    }
    server.shutdown();
}

/// The stats op ('FLST' frames) interleaves with serve traffic on one
/// connection and returns the live Prometheus exposition. Sim-backed:
/// runs on a bare checkout, no artifacts or PJRT needed.
#[test]
fn tcp_stats_op_serves_live_exposition() {
    use flame::config::ModelConfig;
    use flame::dso::{ComputeBackend, SimEngine};

    let (seq, d, tasks) = (16usize, 8usize, 3usize);
    let profiles = vec![4usize, 8];
    let model_cfg = ModelConfig {
        name: "sim".into(),
        seq_len: seq,
        n_blocks: 1,
        layers_per_block: 1,
        d_model: d,
        n_heads: 1,
        n_tasks: tasks,
        m_profiles: profiles.clone(),
        native_m: 8,
    };
    let mut cfg = StackConfig::default();
    cfg.pda.cache_mode = CacheMode::Sync;
    cfg.pda.numa_binding = false;
    let backends: Vec<Arc<dyn ComputeBackend>> = profiles
        .iter()
        .map(|&m| Arc::new(SimEngine::new(m, seq, d, tasks)) as Arc<dyn ComputeBackend>)
        .collect();
    let stack = Arc::new(
        StackBuilder::new("sim", "sim", cfg)
            .build_from_backends(model_cfg, 7, backends)
            .expect("sim stack"),
    );

    let server = TcpServer::start(Arc::clone(&stack), "127.0.0.1:0").expect("start");
    let mut client = TcpClient::connect(&server.addr).expect("connect");

    let before = client.stats().expect("stats before traffic");
    assert!(before.contains("flame_requests_total 0"), "fresh stack: {before}");

    let wire = client.call(&request(1, 4, seq)).expect("call");
    assert_eq!(wire.status, 0);

    let after = client.stats().expect("stats after traffic");
    assert!(after.contains("# TYPE flame_requests_total counter"), "{after}");
    assert!(after.contains("flame_requests_total 1"), "live counter: {after}");
    assert!(after.contains("flame_sla_miss_total{stage=\"compute\"}"), "{after}");

    // the serve stream survives interleaved stats frames
    let wire = client.call(&request(2, 8, seq)).expect("call after stats");
    assert_eq!(wire.status, 0);
    server.shutdown();
}

#[test]
fn tcp_concurrent_clients() {
    let Some(stack) = stack() else { return };
    let server = TcpServer::start(Arc::clone(&stack), "127.0.0.1:0").expect("start");
    let addr = server.addr;
    let l = stack.model_cfg.seq_len;
    let hs: Vec<_> = (0..3)
        .map(|t| {
            std::thread::spawn(move || {
                let mut client = TcpClient::connect(&addr).expect("connect");
                for i in 0..3u64 {
                    let wire = client.call(&request(t * 100 + i, 4, l)).expect("call");
                    assert_eq!(wire.status, 0);
                    assert_eq!(wire.request_id, t * 100 + i);
                }
            })
        })
        .collect();
    for h in hs {
        h.join().unwrap();
    }
    server.shutdown();
}
