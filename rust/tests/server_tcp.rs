//! TCP front integration: start the server on the tiny stack, drive it
//! with the binary-protocol client, check scores match in-process serving.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use flame::cancel::CancelCause;
use flame::config::{CacheMode, StackConfig};
use flame::manifest::testvec::max_abs_diff;
use flame::manifest::Manifest;
use flame::pda::StagingArena;
use flame::runtime::Runtime;
use flame::server::pipeline::StackBuilder;
use flame::server::tcp::{decode_response, encode_request, TcpClient, TcpServer};
use flame::util::bytes::{read_frame, write_frame};
use flame::workload::Request;

fn stack() -> Option<Arc<flame::server::ServingStack>> {
    let manifest = Manifest::load("artifacts").ok()?;
    if !manifest.scenarios.contains_key("tiny") {
        eprintln!("skipping: artifacts/tiny not built");
        return None;
    }
    let rt = match Runtime::new() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping: PJRT runtime unavailable ({e})");
            return None;
        }
    };
    let mut cfg = StackConfig::default();
    cfg.pda.cache_mode = CacheMode::Sync;
    Some(Arc::new(StackBuilder::new("tiny", "fused", cfg).build(&rt, &manifest).ok()?))
}

fn request(id: u64, m: usize, l: usize) -> Request {
    Request {
        request_id: id,
        user_id: id % 10,
        history: (0..l as u64).map(|i| i * 3 + id).collect(),
        candidates: (0..m as u64).map(|i| 1000 + i * 7 + id).collect(),
        ..Default::default()
    }
}

#[test]
fn tcp_roundtrip_matches_inprocess() {
    let Some(stack) = stack() else { return };
    let server = TcpServer::start(Arc::clone(&stack), "127.0.0.1:0").expect("start");
    let mut client = TcpClient::connect(&server.addr).expect("connect");

    let req = request(1, 8, stack.model_cfg.seq_len);
    let wire = client.call(&req).expect("call");
    assert_eq!(wire.status, 0);
    assert_eq!(wire.request_id, 1);
    assert_eq!(wire.m, 8);
    assert_eq!(wire.n_tasks, stack.model_cfg.n_tasks);

    // in-process reference (features are cached+deterministic, so equal)
    let mut arena = StagingArena::new(1 << 16);
    let direct = stack.serve(&req, &mut arena).expect("direct");
    assert!(max_abs_diff(&wire.scores, &direct.scores) < 1e-6);

    server.shutdown();
}

#[test]
fn tcp_multiple_requests_one_connection() {
    let Some(stack) = stack() else { return };
    let server = TcpServer::start(Arc::clone(&stack), "127.0.0.1:0").expect("start");
    let mut client = TcpClient::connect(&server.addr).expect("connect");
    for id in 0..5u64 {
        let m = if id % 2 == 0 { 4 } else { 8 };
        let wire = client.call(&request(id, m, stack.model_cfg.seq_len)).expect("call");
        assert_eq!(wire.status, 0);
        assert_eq!(wire.request_id, id);
        assert_eq!(wire.scores.len(), m * stack.model_cfg.n_tasks);
    }
    server.shutdown();
}

/// Sim-backed stack for tests that must run on a bare checkout (no
/// artifacts or PJRT). `delay` is the per-launch compute time.
fn sim_stack(
    cfgmod: impl FnOnce(&mut StackConfig),
    delay: std::time::Duration,
) -> Arc<flame::server::ServingStack> {
    use flame::config::ModelConfig;
    use flame::dso::{ComputeBackend, SimEngine};

    let (seq, d, tasks) = (16usize, 8usize, 3usize);
    let profiles = vec![4usize, 8];
    let model_cfg = ModelConfig {
        name: "sim".into(),
        seq_len: seq,
        n_blocks: 1,
        layers_per_block: 1,
        d_model: d,
        n_heads: 1,
        n_tasks: tasks,
        m_profiles: profiles.clone(),
        native_m: 8,
    };
    let mut cfg = StackConfig::default();
    cfg.pda.cache_mode = CacheMode::Sync;
    cfg.pda.numa_binding = false;
    cfgmod(&mut cfg);
    let backends: Vec<Arc<dyn ComputeBackend>> = profiles
        .iter()
        .map(|&m| {
            Arc::new(SimEngine::new(m, seq, d, tasks).with_delay(delay))
                as Arc<dyn ComputeBackend>
        })
        .collect();
    Arc::new(
        StackBuilder::new("sim", "sim", cfg)
            .build_from_backends(model_cfg, 7, backends)
            .expect("sim stack"),
    )
}

/// The stats op ('FLST' frames) interleaves with serve traffic on one
/// connection and returns the live Prometheus exposition. Sim-backed:
/// runs on a bare checkout, no artifacts or PJRT needed.
#[test]
fn tcp_stats_op_serves_live_exposition() {
    let seq = 16usize;
    let stack = sim_stack(|_| {}, std::time::Duration::ZERO);

    let server = TcpServer::start(Arc::clone(&stack), "127.0.0.1:0").expect("start");
    let mut client = TcpClient::connect(&server.addr).expect("connect");

    let before = client.stats().expect("stats before traffic");
    assert!(before.contains("flame_requests_total 0"), "fresh stack: {before}");

    let wire = client.call(&request(1, 4, seq)).expect("call");
    assert_eq!(wire.status, 0);

    let after = client.stats().expect("stats after traffic");
    assert!(after.contains("# TYPE flame_requests_total counter"), "{after}");
    assert!(after.contains("flame_requests_total 1"), "live counter: {after}");
    assert!(after.contains("flame_sla_miss_total{stage=\"compute\"}"), "{after}");

    // the serve stream survives interleaved stats frames
    let wire = client.call(&request(2, 8, seq)).expect("call after stats");
    assert_eq!(wire.status, 0);
    server.shutdown();
}

/// A hostile (or framing-buggy) client that sends an absurd length
/// prefix gets a *typed* status-2 error frame — `read_frame` rejects
/// the prefix before allocating the claimed buffer — and then the
/// connection is closed. A well-meaning client can tell its own bug
/// apart from a network drop.
#[test]
fn tcp_oversized_frame_gets_typed_error_then_close() {
    let stack = sim_stack(|_| {}, Duration::ZERO);
    let server = TcpServer::start(stack, "127.0.0.1:0").expect("start");
    let mut conn = TcpStream::connect(server.addr).expect("connect");
    conn.write_all(&u32::MAX.to_le_bytes()).expect("write hostile prefix");
    conn.flush().expect("flush");
    conn.set_read_timeout(Some(Duration::from_secs(5))).unwrap();

    let frame = read_frame(&mut conn, 1 << 20).expect("typed error frame before close");
    let wire = decode_response(&frame).expect("decode error frame");
    assert_eq!(wire.status, 2, "oversized prefix must yield a typed error");

    let mut b = [0u8; 1];
    match conn.read(&mut b) {
        Ok(0) | Err(_) => {} // closed — exactly what we want
        Ok(_) => panic!("connection must be closed after a hostile frame"),
    }
    server.shutdown();
}

/// A frame that parses as a frame but not as a request (garbage
/// payload) gets a typed error and the connection *survives* — only
/// unframeable input forces a close.
#[test]
fn tcp_garbage_payload_gets_typed_error_and_conn_survives() {
    let stack = sim_stack(|_| {}, Duration::ZERO);
    let server = TcpServer::start(stack, "127.0.0.1:0").expect("start");
    let mut conn = TcpStream::connect(server.addr).expect("connect");
    conn.set_read_timeout(Some(Duration::from_secs(5))).unwrap();

    write_frame(&mut conn, &[0u8; 8]).expect("write garbage");
    let frame = read_frame(&mut conn, 1 << 20).expect("typed error frame");
    assert_eq!(decode_response(&frame).expect("decode").status, 2);

    // the same connection still serves a well-formed request
    write_frame(&mut conn, &encode_request(&request(3, 4, 16))).expect("write request");
    let frame = read_frame(&mut conn, 1 << 20).expect("response frame");
    let wire = decode_response(&frame).expect("decode");
    assert_eq!(wire.status, 0);
    assert_eq!(wire.request_id, 3);
    server.shutdown();
}

/// A connection that never sends anything is reclaimed after the idle
/// timeout — a wedged or abandoned peer must not pin a server thread.
#[test]
fn tcp_idle_connection_is_reclaimed() {
    let stack = sim_stack(|_| {}, Duration::ZERO);
    let server =
        TcpServer::start_with_idle_timeout(stack, "127.0.0.1:0", Duration::from_millis(300))
            .expect("start");
    let mut conn = TcpStream::connect(server.addr).expect("connect");
    conn.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let t0 = Instant::now();
    let mut b = [0u8; 1];
    let n = conn.read(&mut b).expect("idle close is a clean EOF, not a reset");
    assert_eq!(n, 0, "server must close the idle connection");
    assert!(
        t0.elapsed() >= Duration::from_millis(250),
        "closed too eagerly: {:?}",
        t0.elapsed()
    );
    server.shutdown();
}

/// The pipelined front serves the same wire protocol: a round trip
/// through submit/reply-channel returns status 0, and the stats op
/// still interleaves.
#[test]
fn tcp_pipeline_front_roundtrip() {
    let stack = sim_stack(|c| c.server.pipeline = true, Duration::ZERO);
    let handle = Arc::new(stack.spawn_pipeline());
    let server = TcpServer::start_pipeline(Arc::clone(&handle), "127.0.0.1:0").expect("start");
    let mut client = TcpClient::connect(&server.addr).expect("connect");

    let wire = client.call(&request(1, 4, 16)).expect("call");
    assert_eq!(wire.status, 0);
    assert_eq!(wire.request_id, 1);
    assert_eq!(wire.scores.len(), 4 * stack.model_cfg.n_tasks);

    let stats = client.stats().expect("stats op on the pipeline front");
    assert!(stats.contains("flame_requests_total"), "{stats}");
    server.shutdown();
}

/// Tentpole, frontend plane: a client that writes one request and
/// vanishes fires `ClientGone` — the doomed work is dropped at a stage
/// boundary (or its finished response discarded at the front) and the
/// cancel ledger counts it exactly once.
#[test]
fn tcp_pipeline_front_counts_vanished_client() {
    let stack = sim_stack(
        |c| {
            c.server.pipeline = true;
            c.server.cancel = true;
        },
        Duration::from_millis(100),
    );
    let handle = Arc::new(stack.spawn_pipeline());
    let server = TcpServer::start_pipeline(Arc::clone(&handle), "127.0.0.1:0").expect("start");
    {
        let mut conn = TcpStream::connect(server.addr).expect("connect");
        write_frame(&mut conn, &encode_request(&request(9, 4, 16))).expect("write request");
        conn.flush().expect("flush");
    } // client vanishes while the stack is still computing (100 ms)

    let t0 = Instant::now();
    while stack.metrics.cancelled_by_cause(CancelCause::ClientGone) == 0
        && t0.elapsed() < Duration::from_secs(5)
    {
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(
        stack.metrics.cancelled_by_cause(CancelCause::ClientGone),
        1,
        "the vanished client's request must be counted exactly once"
    );
    server.shutdown();
}

#[test]
fn tcp_concurrent_clients() {
    let Some(stack) = stack() else { return };
    let server = TcpServer::start(Arc::clone(&stack), "127.0.0.1:0").expect("start");
    let addr = server.addr;
    let l = stack.model_cfg.seq_len;
    let hs: Vec<_> = (0..3)
        .map(|t| {
            std::thread::spawn(move || {
                let mut client = TcpClient::connect(&addr).expect("connect");
                for i in 0..3u64 {
                    let wire = client.call(&request(t * 100 + i, 4, l)).expect("call");
                    assert_eq!(wire.status, 0);
                    assert_eq!(wire.request_id, t * 100 + i);
                }
            })
        })
        .collect();
    for h in hs {
        h.join().unwrap();
    }
    server.shutdown();
}
