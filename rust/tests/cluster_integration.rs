//! Cluster-tier integration over simulated replicas: policy behavior,
//! cache-affinity hit-rate lift, deadline admission under saturation,
//! and replica failure ejection / failover / re-admission. No artifacts
//! required.

use std::sync::Arc;
use std::time::Duration;

use flame::cluster::{
    ClusterConfig, ClusterRouter, ReplicaBackend, RoutePolicy, SimConfig, SimReplica,
};
use flame::error::Error;
use flame::workload::{driver, Request};

fn fast_sim() -> SimConfig {
    SimConfig { base_us: 0, per_pair_ns: 0, miss_penalty_us: 0, ..SimConfig::default() }
}

fn build(
    n: usize,
    policy: RoutePolicy,
    sim: SimConfig,
    cfg_mod: impl FnOnce(&mut ClusterConfig),
) -> (Vec<Arc<SimReplica>>, Arc<ClusterRouter>) {
    let sims: Vec<Arc<SimReplica>> = (0..n).map(|_| Arc::new(SimReplica::new(sim.clone()))).collect();
    let backends: Vec<Arc<dyn ReplicaBackend>> =
        sims.iter().map(|s| Arc::clone(s) as Arc<dyn ReplicaBackend>).collect();
    let mut cfg = ClusterConfig { policy, slots_per_replica: sim.slots, ..ClusterConfig::default() };
    cfg_mod(&mut cfg);
    let router = Arc::new(ClusterRouter::new(backends, cfg).unwrap());
    (sims, router)
}

fn req(id: u64, user: u64, m: usize) -> Request {
    Request {
        request_id: id,
        user_id: user,
        history: vec![],
        candidates: (0..m as u64).collect(),
        ..Default::default()
    }
}

/// 61 users x 8 rounds through both policies: affinity pins each user to
/// one replica (1 cold miss per user), round-robin rotates each user
/// over all replicas (61 ≡ 1 mod 3, so a user's replica shifts every
/// round and every cache must warm separately) — affinity's aggregate
/// hit rate must come out strictly higher.
#[test]
fn affinity_beats_round_robin_on_cache_hit_rate() {
    const USERS: u64 = 61;
    const ROUNDS: u64 = 8;
    let mut rates = Vec::new();
    for policy in [RoutePolicy::CacheAffinity, RoutePolicy::RoundRobin] {
        let (_, router) = build(3, policy, fast_sim(), |_| {});
        for round in 0..ROUNDS {
            for user in 0..USERS {
                router.submit(&req(round * USERS + user, user, 4)).unwrap();
            }
        }
        rates.push(router.aggregate_cache_hit_rate());
    }
    let (affinity, rr) = (rates[0], rates[1]);
    assert!(
        affinity > rr,
        "affinity hit rate {affinity:.3} must strictly beat round-robin {rr:.3}"
    );
    // affinity: exactly one cold miss per user
    let expect = ((USERS * ROUNDS - USERS) as f64) / ((USERS * ROUNDS) as f64);
    assert!((affinity - expect).abs() < 1e-9, "affinity rate {affinity} != {expect}");
}

#[test]
fn affinity_placement_is_deterministic_across_routers() {
    let (a_sims, a) = build(4, RoutePolicy::CacheAffinity, fast_sim(), |_| {});
    let (b_sims, b) = build(4, RoutePolicy::CacheAffinity, fast_sim(), |_| {});
    for user in 0..200u64 {
        a.submit(&req(user, user, 2)).unwrap();
        b.submit(&req(user, user, 2)).unwrap();
    }
    for i in 0..4 {
        assert_eq!(
            a.replicas()[i].metrics.requests(),
            b.replicas()[i].metrics.requests(),
            "replica {i} request counts diverge"
        );
        assert_eq!(a_sims[i].served_total(), b_sims[i].served_total());
    }
}

/// Saturate 2 replicas x 1 slot of 2 ms service with 16 concurrent
/// submitters under a 5 ms budget: the estimator must start shedding
/// once queues build, and shed requests surface as `Overloaded`.
#[test]
fn admission_sheds_under_saturation() {
    let sim = SimConfig { base_us: 2_000, per_pair_ns: 0, miss_penalty_us: 0, slots: 1, ..SimConfig::default() };
    let (_, router) = build(2, RoutePolicy::LeastLoaded, sim, |c| c.deadline_ms = 5);
    let requests: Vec<Request> = (0..400).map(|i| req(i, i, 2)).collect();
    let mut overloaded = 0u64;
    let report = driver::closed_loop(requests, 16, Duration::from_secs(30), |r| {
        match router.submit(r) {
            Ok(_) => true,
            Err(Error::Overloaded(_)) => false,
            Err(e) => panic!("unexpected error class: {e}"),
        }
    });
    overloaded += report.rejected;
    assert!(router.admission.shed() > 0, "saturation must shed");
    assert_eq!(router.admission.shed(), overloaded, "sheds all surface as Overloaded");
    assert!(report.completed > 0, "the cluster still serves what fits the SLA");
}

#[test]
fn failing_replica_is_ejected_and_traffic_fails_over() {
    let (sims, router) = build(3, RoutePolicy::CacheAffinity, fast_sim(), |c| {
        c.eject_after = 3;
        c.eject_cooldown_ms = 100;
    });
    sims[0].fail_next(u32::MAX);
    // every request must still succeed: failover re-routes around the
    // dead replica, and after 3 errors it is ejected entirely
    for i in 0..300u64 {
        router.submit(&req(i, i, 2)).unwrap();
    }
    let snap = router.snapshot();
    assert!(snap.replicas[0].ejections >= 1, "replica 0 never ejected");
    assert!(snap.rerouted >= 3, "failed attempts must have failed over");
    assert_eq!(
        snap.replicas[1].requests + snap.replicas[2].requests,
        300,
        "all traffic landed on the healthy replicas"
    );
}

#[test]
fn ejected_replica_readmitted_by_canary_after_cooldown() {
    let (sims, router) = build(2, RoutePolicy::RoundRobin, fast_sim(), |c| {
        c.eject_after = 2;
        c.eject_cooldown_ms = 50;
    });
    // 3 failures: two eject replica 0 during the first phase, one is
    // left over to burn the first post-cooldown canary
    sims[0].fail_next(3);
    for i in 0..20u64 {
        router.submit(&req(i, i, 2)).unwrap();
    }
    assert!(!router.replicas()[0].healthy(), "replica 0 should be ejected");
    std::thread::sleep(Duration::from_millis(60));
    // half-open: the cooldown alone no longer restores health — the
    // replica owes one successful canary first
    assert!(
        !router.replicas()[0].healthy(),
        "cooldown passed but no canary succeeded yet: still not healthy"
    );
    assert!(router.replicas()[0].probing(), "replica 0 must be probe-eligible");
    // this submission spends the canary on the leftover injected
    // failure: the probe fails, replica 0 re-ejects for another
    // cooldown, and the request itself still succeeds via failover
    router.submit(&req(50, 0, 2)).unwrap();
    assert_eq!(router.replicas()[0].probes_failed_total(), 1);
    assert!(!router.replicas()[0].healthy(), "failed canary re-ejects");
    std::thread::sleep(Duration::from_millis(60));
    // second canary hits a recovered backend: full traffic returns
    let before = router.replicas()[0].metrics.requests();
    for i in 0..20u64 {
        router.submit(&req(100 + i, i, 2)).unwrap();
    }
    assert_eq!(router.replicas()[0].probes_ok_total(), 1, "exactly one canary succeeded");
    assert!(router.replicas()[0].healthy(), "successful canary restores health");
    assert!(
        router.replicas()[0].metrics.requests() > before,
        "re-admitted replica serves again"
    );
    let snap = router.snapshot();
    assert_eq!((snap.probes_ok, snap.probes_failed), (1, 1));
}

#[test]
fn whole_fleet_down_is_overloaded_not_panic() {
    let (sims, router) = build(2, RoutePolicy::LeastLoaded, fast_sim(), |c| {
        c.eject_after = 1;
        c.eject_cooldown_ms = 10_000;
    });
    for s in &sims {
        s.fail_next(u32::MAX);
    }
    // first submissions burn through failover until both are ejected
    for i in 0..10u64 {
        let _ = router.submit(&req(i, i, 2));
    }
    match router.submit(&req(99, 99, 2)) {
        Err(Error::Overloaded(msg)) => assert!(msg.contains("no healthy"), "{msg}"),
        other => panic!("expected Overloaded(no healthy replicas), got {other:?}"),
    }
}
