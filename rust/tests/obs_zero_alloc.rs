//! Acceptance: tracing must be free when it is off. With
//! `trace_sample_n = 0` (no tracer attached — exactly what the CLI wires
//! up) the admission check is one `OnceLock` load; with a tracer
//! attached but the sampling draw lost, the context carries only Copy
//! ids and an empty, never-growing span vec. Neither path may touch the
//! allocator. A counting `#[global_allocator]` proves it; this file
//! holds a single test so no concurrent test pollutes the counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use flame::metrics::Recorder;
use flame::obs::{StageKind, Tracer};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

// SAFETY: every method forwards verbatim to `System`, which upholds the
// GlobalAlloc contract; the only extra work is a Relaxed counter bump,
// which never allocates, unwinds, or touches the returned pointers.
unsafe impl GlobalAlloc for CountingAlloc {
    // SAFETY: delegates to System.alloc under the caller's layout contract.
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    // SAFETY: delegates to System.dealloc; ptr/layout come from alloc above.
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    // SAFETY: delegates to System.realloc under the caller's contract.
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn disabled_and_unsampled_tracing_never_allocate() {
    // --- tracing off: no tracer attached (trace_sample_n = 0) ---
    let off = Recorder::new();
    for i in 0..8u64 {
        assert!(off.trace_begin(i, 50_000).is_none()); // warmup
    }
    let before = ALLOCS.load(Ordering::Relaxed);
    for i in 0..1_000u64 {
        assert!(off.trace_begin(i, 50_000).is_none());
    }
    assert_eq!(
        ALLOCS.load(Ordering::Relaxed),
        before,
        "trace_begin allocated with tracing disabled"
    );

    // --- tracer attached, request loses the 1-in-N sampling draw ---
    let rec = Recorder::new();
    rec.set_tracer(Arc::new(Tracer::new(1_000_000)), 0);
    // warmup: admit 0 wins the draw (0 % N == 0) and pays its span vec
    // here; also faults in thread-locals and lazy lock state
    for i in 0..8u64 {
        let mut ctx = rec.trace_begin(i, 50_000).expect("tracer attached");
        ctx.span_ending_now(StageKind::Compute, 5);
        rec.trace_finish(ctx, false);
    }
    let before = ALLOCS.load(Ordering::Relaxed);
    for i in 0..1_000u64 {
        let mut ctx = rec.trace_begin(i, 50_000).expect("tracer attached");
        assert!(!ctx.sampled(), "admits 8..1008 must all lose a 1-in-1e6 draw");
        ctx.span_ending_now(StageKind::Compute, 5);
        ctx.span_linked(StageKind::Feature, 0, 1, &[7]);
        ctx.link_last(3);
        rec.trace_finish(ctx, false);
    }
    assert_eq!(
        ALLOCS.load(Ordering::Relaxed),
        before,
        "unsampled request paid an allocation on the hot path"
    );
}
