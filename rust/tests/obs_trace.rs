//! Request-scoped tracing end to end over the artifact-free `SimEngine`
//! backend: cross-request launch causality (every rider of a coalesced
//! launch flow-links to the same launch span), SLA-miss attribution
//! (the exemplar's verdict names the stage a known-injected delay made
//! dominant), and the Chrome-trace export of a real run.

use std::sync::{Arc, Barrier};
use std::time::Duration;

use flame::config::{CacheMode, ModelConfig, StackConfig};
use flame::dso::{ComputeBackend, SimEngine};
use flame::netsim::{Link, LinkConfig};
use flame::obs::{export, StageKind, Tracer};
use flame::pda::StagingArena;
use flame::server::pipeline::StackBuilder;
use flame::server::ServingStack;
use flame::workload::Request;

const SEQ: usize = 16;
const D: usize = 8;
const TASKS: usize = 3;
const PROFILES: [usize; 2] = [4, 8];
const SEED: u64 = 99;

fn model_cfg() -> ModelConfig {
    ModelConfig {
        name: "sim".into(),
        seq_len: SEQ,
        n_blocks: 1,
        layers_per_block: 1,
        d_model: D,
        n_heads: 1,
        n_tasks: TASKS,
        m_profiles: PROFILES.to_vec(),
        native_m: PROFILES[PROFILES.len() - 1],
    }
}

fn link(rtt: Duration) -> Arc<Link> {
    Arc::new(Link::new(LinkConfig { rtt, bandwidth_bps: 1e9, jitter: 0.0, fail_rate: 0.0 }))
}

fn sim_stack(
    cfgmod: impl FnOnce(&mut StackConfig),
    delay: Duration,
    link: Arc<Link>,
) -> Arc<ServingStack> {
    let mut cfg = StackConfig::default();
    cfg.pda.cache_mode = CacheMode::Sync;
    cfg.pda.numa_binding = false;
    cfgmod(&mut cfg);
    let backends: Vec<Arc<dyn ComputeBackend>> = PROFILES
        .iter()
        .map(|&m| {
            Arc::new(SimEngine::new(m, SEQ, D, TASKS).with_delay(delay))
                as Arc<dyn ComputeBackend>
        })
        .collect();
    Arc::new(
        StackBuilder::new("sim", "sim", cfg)
            .with_link(link)
            .build_from_backends(model_cfg(), SEED, backends)
            .expect("sim stack"),
    )
}

fn request(id: u64, m: usize, salt: u64) -> Request {
    Request {
        request_id: id,
        user_id: salt % 100,
        history: vec![salt, salt + 1, salt + 2],
        candidates: (0..m as u64).map(|i| salt.wrapping_mul(17) ^ (i << 8)).collect(),
        ..Default::default()
    }
}

/// Tentpole acceptance: four concurrent 1-candidate requests coalesce
/// into one profile-4 engine launch; every request's trace must carry a
/// Compute span linked to the *same* launch span id, and that launch's
/// shared span must list all four riders.
#[test]
fn coalesced_launch_links_every_rider_trace() {
    let stack = sim_stack(
        |c| {
            c.dso.coalesce = true;
            // long flush bound: only a full batch dispatches, so all
            // four rows deterministically share one launch
            c.dso.coalesce_wait_us = 500_000;
        },
        Duration::ZERO,
        link(Duration::from_micros(200)),
    );
    let tracer = Arc::new(Tracer::new(1));
    stack.metrics.set_tracer(Arc::clone(&tracer), 0);

    const N: usize = 4; // == smallest profile: the 4th row closes the batch
    let barrier = Arc::new(Barrier::new(N));
    std::thread::scope(|s| {
        for i in 0..N as u64 {
            let stack = Arc::clone(&stack);
            let barrier = Arc::clone(&barrier);
            s.spawn(move || {
                let mut arena = StagingArena::new(stack.arena_capacity());
                let req = request(i, 1, (i + 1) * 1_000);
                barrier.wait();
                stack.serve(&req, &mut arena).expect("served");
            });
        }
    });

    let dump = tracer.dump();
    assert_eq!(dump.traces.len(), N, "sample_n=1 must retain every trace");

    // each trace's Compute span links exactly the launches it rode
    let mut launch_links: Vec<u64> = Vec::new();
    for t in &dump.traces {
        let compute = t
            .spans
            .iter()
            .find(|s| s.kind == StageKind::Compute)
            .expect("every trace records its compute stage");
        assert_eq!(
            compute.links.len(),
            1,
            "one coalesced launch per request, got {:?}",
            compute.links
        );
        launch_links.push(compute.links[0]);
    }
    let first = launch_links[0];
    assert!(first != 0);
    assert!(
        launch_links.iter().all(|&l| l == first),
        "all riders must link the same launch span, got {launch_links:?}"
    );

    // the launch's shared span names every rider, and only those
    let launch = dump
        .shared
        .iter()
        .find(|s| s.span_id == first)
        .expect("launch span retained");
    assert_eq!(launch.kind, StageKind::Launch);
    let mut members = launch.member_traces.clone();
    members.sort_unstable();
    let mut expected: Vec<u64> = dump.traces.iter().map(|t| t.trace_id).collect();
    expected.sort_unstable();
    assert_eq!(members, expected, "launch span must list all four riders");

    // and the whole thing exports as valid Chrome trace JSON with the
    // rider→launch flow arrows intact
    let json = export::chrome_trace_json(&dump);
    let check = export::validate_chrome_trace(&json).expect("valid trace JSON");
    assert!(check.flow_starts >= N, "one flow arrow per rider, got {check:?}");
    assert_eq!(check.flow_starts, check.flow_ends, "unpaired flow events");
}

/// SLA attribution, compute-dominant: a 30 ms injected engine delay
/// against a 1 ms deadline must yield an SLA-miss exemplar whose verdict
/// is Compute, mirrored into the recorder's per-stage miss counters.
#[test]
fn sla_miss_attributes_injected_compute_delay() {
    let stack = sim_stack(
        |c| c.server.deadline_ms = 1,
        Duration::from_millis(30), // the known slow stage
        link(Duration::from_micros(200)),
    );
    let tracer = Arc::new(Tracer::new(1));
    stack.metrics.set_tracer(Arc::clone(&tracer), 0);

    let mut arena = StagingArena::new(stack.arena_capacity());
    stack.serve(&request(1, 2, 42), &mut arena).expect("served (late, but served)");

    let dump = tracer.dump();
    assert_eq!(dump.sla.len(), 1, "the blown deadline must leave an exemplar");
    let miss = &dump.sla[0];
    assert!(miss.sla_missed);
    assert!(miss.total_us > miss.budget_us, "{miss:?}");
    assert_eq!(
        miss.verdict,
        Some(StageKind::Compute),
        "verdict must name the injected 30 ms stage"
    );
    let (q, f, h, c, o) = stack.metrics.sla_miss_attribution();
    assert_eq!((q, f, h, c, o), (0, 0, 0, 1, 0), "recorder mirror disagrees");
    let snap = stack.metrics.snapshot();
    assert_eq!(snap.sla_miss_compute, 1);
}

/// SLA attribution, feature-dominant: same deadline, zero compute delay,
/// but a 40 ms feature-store round trip — the verdict must flip.
#[test]
fn sla_miss_attributes_slow_feature_store() {
    let stack = sim_stack(
        |c| c.server.deadline_ms = 1,
        Duration::ZERO,
        link(Duration::from_millis(40)), // sync-mode miss pays this rtt
    );
    let tracer = Arc::new(Tracer::new(1));
    stack.metrics.set_tracer(Arc::clone(&tracer), 0);

    let mut arena = StagingArena::new(stack.arena_capacity());
    stack.serve(&request(1, 2, 7), &mut arena).expect("served");

    let dump = tracer.dump();
    assert_eq!(dump.sla.len(), 1);
    assert_eq!(
        dump.sla[0].verdict,
        Some(StageKind::Feature),
        "verdict must follow the dominant stage, not a fixed one"
    );
    let (_, f, _, c, _) = stack.metrics.sla_miss_attribution();
    assert_eq!((f, c), (1, 0));
}

/// Deadline-respecting runs leave no SLA exemplars and no attribution
/// counts — the tail stores only ever hold real misses.
#[test]
fn on_budget_requests_leave_no_sla_exemplars() {
    let stack = sim_stack(|_| {}, Duration::ZERO, link(Duration::from_micros(200)));
    let tracer = Arc::new(Tracer::new(1));
    stack.metrics.set_tracer(Arc::clone(&tracer), 0);
    let mut arena = StagingArena::new(stack.arena_capacity());
    for i in 0..8 {
        stack.serve(&request(i, 2, i + 1), &mut arena).expect("served");
    }
    let dump = tracer.dump();
    assert_eq!(dump.traces.len(), 8);
    assert!(dump.sla.is_empty(), "no deadline was missed: {:?}", dump.sla);
    assert_eq!(stack.metrics.sla_miss_attribution(), (0, 0, 0, 0, 0));
}
