//! Cluster result-cache tier integration: property-style score fidelity
//! (a cache hit is bit-identical to recomputation, including candidate
//! order remapping), single-flight coalescing (N concurrent duplicates
//! → exactly 1 backend serve), TTL expiry, and the disabled-tier
//! baseline. No artifacts required.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use flame::cluster::{
    ClusterConfig, ClusterRouter, ReplicaBackend, ResultCacheConfig,
};
use flame::error::Result;
use flame::server::pipeline::Response;
use flame::util::rng::{splitmix64, Rng};
use flame::workload::Request;

const N_TASKS: usize = 3;

/// Deterministic per-(user, candidate, task) score — what a fixed model
/// would produce, so "score-identical to recomputation" is exact.
fn score(user: u64, candidate: u64, task: usize) -> f32 {
    let mut s = user ^ candidate.rotate_left(17) ^ ((task as u64) << 49);
    (splitmix64(&mut s) % 10_000) as f32 / 10_000.0
}

/// Backend that scores deterministically and counts its serve calls.
struct ScoringBackend {
    serves: AtomicU64,
    delay: Duration,
}

impl ScoringBackend {
    fn new(delay: Duration) -> Self {
        ScoringBackend { serves: AtomicU64::new(0), delay }
    }

    fn serves(&self) -> u64 {
        self.serves.load(Ordering::Relaxed)
    }
}

impl ReplicaBackend for ScoringBackend {
    fn serve(&self, req: &Request) -> Result<Response> {
        self.serves.fetch_add(1, Ordering::Relaxed);
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        let mut scores = Vec::with_capacity(req.m() * N_TASKS);
        for &c in &req.candidates {
            for t in 0..N_TASKS {
                scores.push(score(req.user_id, c, t));
            }
        }
        Ok(Response {
            request_id: req.request_id,
            scores,
            m: req.m(),
            overall_us: 1,
            compute_us: 1,
            feature_us: 0,
            queue_us: 0,
            handoff_us: 0,
            quality: flame::chaos::ServeQuality::Full,
        })
    }
}

fn router_with(
    backends: Vec<Arc<ScoringBackend>>,
    coalesce: bool,
    ttl_ms: u64,
) -> ClusterRouter {
    let b: Vec<Arc<dyn ReplicaBackend>> =
        backends.into_iter().map(|x| x as Arc<dyn ReplicaBackend>).collect();
    ClusterRouter::new(
        b,
        ClusterConfig {
            deadline_ms: 10_000,
            result_cache: ResultCacheConfig {
                capacity: 4_096,
                ttl_ms,
                coalesce,
                ..ResultCacheConfig::default()
            },
            ..ClusterConfig::default()
        },
    )
    .unwrap()
}

fn shuffle(v: &mut [u64], rng: &mut Rng) {
    for i in (1..v.len()).rev() {
        let j = (rng.next_u64() as usize) % (i + 1);
        v.swap(i, j);
    }
}

/// Property: for random (user, candidate-set) requests, a result-cache
/// hit — including one whose candidate order is a permutation of the
/// cached layout — returns exactly the scores a fresh computation
/// would, row-mapped to the requester's order.
#[test]
fn cache_hits_are_score_identical_to_recomputation() {
    let backend = Arc::new(ScoringBackend::new(Duration::ZERO));
    let reference = ScoringBackend::new(Duration::ZERO);
    let router = router_with(vec![Arc::clone(&backend)], true, 60_000);
    let mut rng = Rng::new(0xFEED);
    for i in 0..300u64 {
        let user = rng.next_u64() % 40;
        let m = 2 + (rng.next_u64() % 6) as usize;
        let mut candidates: Vec<u64> = (0..m).map(|_| 1 + rng.next_u64() % 500).collect();
        let history = vec![user, user ^ 7];
        let first = Request {
            request_id: i * 2,
            user_id: user,
            history: history.clone(),
            candidates: candidates.clone(),
            ..Default::default()
        };
        router.submit(&first).unwrap();
        // permute the candidate order: same multiset, different layout
        shuffle(&mut candidates, &mut rng);
        let dup = Request {
            request_id: i * 2 + 1,
            user_id: user,
            history,
            candidates,
            ..Default::default()
        };
        let served = router.submit(&dup).unwrap();
        let recomputed = reference.serve(&dup).unwrap();
        assert_eq!(
            served.scores, recomputed.scores,
            "iteration {i}: cache hit diverged from recomputation"
        );
        assert_eq!(served.request_id, dup.request_id);
        assert_eq!(served.m, dup.m());
    }
    let snap = router.snapshot();
    assert!(
        snap.result_hits >= 300,
        "every permuted duplicate must hit the result tier, got {}",
        snap.result_hits
    );
    assert_eq!(
        backend.serves() + snap.result_hits + snap.result_coalesced,
        600,
        "every submission either computed once or rode the cache"
    );
}

/// N concurrent identical submissions produce exactly 1 backend serve:
/// the first becomes the single-flight leader, the rest coalesce onto
/// its computation (or hit the cache if they arrive after it lands).
#[test]
fn concurrent_duplicates_coalesce_to_one_backend_serve() {
    const THREADS: u64 = 8;
    let backend = Arc::new(ScoringBackend::new(Duration::from_millis(100)));
    let router = Arc::new(router_with(vec![Arc::clone(&backend)], true, 60_000));
    let barrier = Arc::new(std::sync::Barrier::new(THREADS as usize));
    let responses: Vec<Response> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..THREADS)
            .map(|i| {
                let router = Arc::clone(&router);
                let barrier = Arc::clone(&barrier);
                s.spawn(move || {
                    barrier.wait();
                    let req = Request {
                        request_id: i,
                        user_id: 5,
                        history: vec![5, 6],
                        candidates: vec![10, 20, 30],
                        ..Default::default()
                    };
                    router.submit(&req).unwrap()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    assert_eq!(
        backend.serves(),
        1,
        "{THREADS} concurrent duplicates must fan in to exactly 1 backend serve"
    );
    let snap = router.snapshot();
    assert_eq!(snap.result_misses, 1, "exactly one leader");
    assert_eq!(snap.result_hits + snap.result_coalesced, THREADS - 1);
    assert!(snap.result_coalesced >= 1, "at least one request must have coalesced");
    for (i, r) in responses.iter().enumerate() {
        assert_eq!(r.request_id, i as u64, "each requester keeps its own id");
        assert_eq!(r.scores, responses[0].scores, "coalesced scores must match the leader's");
    }
    assert_eq!(router.metrics.requests(), THREADS, "all completions count in router throughput");
}

/// An expired result recomputes instead of serving stale scores.
#[test]
fn expired_results_recompute() {
    let backend = Arc::new(ScoringBackend::new(Duration::ZERO));
    let router = router_with(vec![Arc::clone(&backend)], true, 20);
    let req = |id| Request {
        request_id: id,
        user_id: 1,
        history: vec![1],
        candidates: vec![4, 2],
        ..Default::default()
    };
    router.submit(&req(0)).unwrap();
    std::thread::sleep(Duration::from_millis(60));
    router.submit(&req(1)).unwrap();
    assert_eq!(backend.serves(), 2, "expired entry must recompute");
    let snap = router.snapshot();
    assert_eq!(snap.result_hits, 0);
    assert_eq!(snap.result_misses, 2);
}

/// Regression: `invalidate_user` landing while a single-flight leader
/// is mid-computation must not be undone by the leader's insert. Before
/// the publication-time epoch re-check, the evictor found nothing to
/// evict (nothing published yet), the leader then published, and the
/// next duplicate *hit* a row scored from pre-update features. Now the
/// late insert self-evicts and the duplicate recomputes.
#[test]
fn invalidation_during_leader_flight_is_not_resurrected() {
    let backend = Arc::new(ScoringBackend::new(Duration::from_millis(120)));
    let router = Arc::new(router_with(vec![Arc::clone(&backend)], true, 60_000));
    let req = |id| Request {
        request_id: id,
        user_id: 77,
        history: vec![77],
        candidates: vec![10, 20],
        ..Default::default()
    };
    std::thread::scope(|s| {
        let r2 = Arc::clone(&router);
        let leader = s.spawn(move || r2.submit(&req(0)).unwrap());
        // let the leader register its flight and enter the backend...
        for _ in 0..2_000 {
            if backend.serves() > 0 {
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        assert!(backend.serves() > 0, "leader never reached the backend");
        // ...then the feature update lands mid-flight
        assert_eq!(router.invalidate_user(77), 0, "nothing published yet to evict");
        leader.join().unwrap();
    });
    // the leader has published since: a duplicate must recompute, not
    // hit the resurrected pre-update row
    router.submit(&req(1)).unwrap();
    assert_eq!(backend.serves(), 2, "post-invalidation duplicate must reach the backend");
    let snap = router.snapshot();
    assert_eq!(snap.result_hits, 0, "stale row must not serve a hit");
}

/// `capacity == 0` disables the tier entirely: every submission reaches
/// a replica and the counters stay zero.
#[test]
fn disabled_tier_reaches_backend_every_time() {
    let backend = Arc::new(ScoringBackend::new(Duration::ZERO));
    let router = ClusterRouter::new(
        vec![Arc::clone(&backend) as Arc<dyn ReplicaBackend>],
        ClusterConfig::default(),
    )
    .unwrap();
    assert!(router.result_cache().is_none());
    for i in 0..5 {
        let req = Request {
            request_id: i,
            user_id: 9,
            history: vec![9],
            candidates: vec![1, 2],
            ..Default::default()
        };
        router.submit(&req).unwrap();
    }
    assert_eq!(backend.serves(), 5);
    let snap = router.snapshot();
    assert_eq!((snap.result_hits, snap.result_misses, snap.result_coalesced), (0, 0, 0));
}
