//! Storm-scenario integration: the multi-tenant isolation invariant.
//!
//! One seeded storm timeline — tenant 1 flash crowd over a quiet tenant
//! 0 — replays against two otherwise-identical clusters, one with the
//! feedback overload controller armed and one without. The controller
//! arm must keep the quiet tenant's SLA-miss rate near its quiet-phase
//! baseline while the flash crowd pays its own overload bill; the open
//! loop arm must be measurably worse for the bystander; and the shed
//! level must decay back to zero once the storm passes. No artifacts
//! required (simulated replicas with real slot queueing).

use std::sync::Arc;

use flame::cluster::{
    ClusterConfig, ClusterRouter, ReplicaBackend, RoutePolicy, SimConfig, SimReplica, TenantSet,
};
use flame::config::WorkloadConfig;
use flame::metrics::TenantCounts;
use flame::workload::storm::StormSpec;
use flame::workload::trace::TraceEvent;
use flame::workload::{driver, Generator, TenantId};

/// Phase boundaries (µs): quiet warm-up, flash-crowd storm, recovery.
const PHASES: [(u64, u64); 3] = [(0, 1_000_000), (1_000_000, 3_000_000), (3_000_000, 4_500_000)];

/// Per-phase, per-tenant deltas of the cumulative tenant counters.
#[derive(Clone, Copy, Default)]
struct PhaseCounts {
    requests: u64,
    sla_miss: u64,
    shed: u64,
}

impl PhaseCounts {
    fn miss_rate(self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.sla_miss as f64 / self.requests as f64
        }
    }

    /// Fraction of everything submitted that missed or was refused —
    /// the bystander's total pain, however it was inflicted.
    fn bad_rate(self) -> f64 {
        let submitted = self.requests + self.shed;
        if submitted == 0 {
            0.0
        } else {
            (self.sla_miss + self.shed) as f64 / submitted as f64
        }
    }
}

fn diff(after: &TenantCounts, before: &TenantCounts) -> PhaseCounts {
    PhaseCounts {
        requests: after.requests - before.requests,
        sla_miss: after.sla_miss - before.sla_miss,
        shed: after.shed - before.shed,
    }
}

/// Slice `events` to `[lo, hi)` and rebase offsets to the phase start,
/// so each phase replays from its own t=0 (the inter-phase join also
/// drains the cluster, keeping phase attribution exact).
fn rebase(events: &[TraceEvent], lo: u64, hi: u64) -> Vec<TraceEvent> {
    events
        .iter()
        .filter(|e| (lo..hi).contains(&e.at_us()))
        .map(|e| match e {
            TraceEvent::Arrival { at_us, req } => {
                TraceEvent::Arrival { at_us: at_us - lo, req: req.clone() }
            }
            TraceEvent::InvalidateUser { at_us, user_id } => {
                TraceEvent::InvalidateUser { at_us: at_us - lo, user_id: *user_id }
            }
        })
        .collect()
}

struct ArmOutcome {
    /// `[phase][tenant]` deltas for tenants 0 and 1.
    phases: [[PhaseCounts; 2]; 3],
    final_shed_permille_t1: u64,
}

/// Replay the identical timeline against a fresh 2-replica cluster.
/// Capacity: 2 replicas x 2 slots / 2.5 ms service = ~1600 req/s; the
/// storm offers ~3000 req/s, so the flash crowd genuinely overloads it.
fn run_arm(controller: bool, events: &[TraceEvent]) -> ArmOutcome {
    let sim = SimConfig {
        base_us: 2_500,
        per_pair_ns: 0,
        miss_penalty_us: 0,
        slots: 2,
        ..SimConfig::default()
    };
    let backends: Vec<Arc<dyn ReplicaBackend>> = (0..2)
        .map(|_| Arc::new(SimReplica::new(sim.clone())) as Arc<dyn ReplicaBackend>)
        .collect();
    let cfg = ClusterConfig {
        policy: RoutePolicy::LeastLoaded,
        deadline_ms: 20,
        slots_per_replica: 2,
        controller,
        tenants: TenantSet::parse("t0:w=1,t1:w=1").unwrap(),
        ..ClusterConfig::default()
    };
    let router = Arc::new(ClusterRouter::new(backends, cfg).unwrap());

    let mut phases = [[PhaseCounts::default(); 2]; 3];
    let mut before = router.metrics.tenant_counts();
    for (p, &(lo, hi)) in PHASES.iter().enumerate() {
        let slice = rebase(events, lo, hi);
        driver::open_loop_events(
            &slice,
            1.0,
            64,
            |r| router.submit(r).is_ok(),
            |u| {
                router.invalidate_user(u);
            },
        );
        let after = router.metrics.tenant_counts();
        for t in 0..2 {
            phases[p][t] = diff(&after[t], &before[t]);
        }
        before = after;
    }
    ArmOutcome {
        phases,
        final_shed_permille_t1: router
            .controller()
            .map_or(0, |c| c.shed_permille(TenantId(1))),
    }
}

/// The tentpole invariant: one tenant's flash crowd must not take the
/// other tenant down with it — and turning the controller off must make
/// the bystander measurably worse on the byte-identical storm.
#[test]
fn flash_crowd_on_tenant_1_leaves_tenant_0_sla_intact_under_controller() {
    let wl = WorkloadConfig {
        catalog_size: 10_000,
        zipf_theta: 0.99,
        n_users: 2_000,
        candidate_mix: vec![(16, 1.0)],
        arrival_rate: None,
        seed: 41,
    };
    // tenant 1 x9 flash over [1s, 3s) concentrated on 64 hot items,
    // plus a feature-update storm inside the same window
    let spec = StormSpec::parse(
        "flash:tenant=1,at_s=1,for_s=2,x=9,hot=64,\
         invalidate:rate=100,at_s=1,for_s=2,mix:w0=1,w1=1",
    )
    .unwrap();
    let events = spec.generate(&mut Generator::new(&wl, 16), 600.0, 4.5, 41);
    assert!(
        events.iter().any(|e| matches!(e, TraceEvent::InvalidateUser { .. })),
        "the scenario exercises the invalidation replay path"
    );
    let arrivals = |t: u8| {
        events
            .iter()
            .filter(
                |e| matches!(e, TraceEvent::Arrival { req, .. } if req.tenant == TenantId(t)),
            )
            .count()
    };
    assert!(arrivals(0) > 500 && arrivals(1) > arrivals(0), "storm shape sanity");

    // both arms consume the same `events` vec: identical storms by
    // construction (StormSpec::generate determinism is unit-tested)
    let on = run_arm(true, &events);
    let off = run_arm(false, &events);

    let quiet_b = on.phases[0][0];
    let storm_b_on = on.phases[1][0];
    let storm_b_off = off.phases[1][0];

    assert!(
        quiet_b.miss_rate() < 0.05,
        "quiet-phase baseline should be clean: miss rate {:.3} over {} requests",
        quiet_b.miss_rate(),
        quiet_b.requests
    );
    // isolation: B's storm miss rate stays within 2x its quiet baseline
    // (+ a transient allowance for the feedback loop's first ticks)
    assert!(
        storm_b_on.miss_rate() <= 2.0 * quiet_b.miss_rate() + 0.2,
        "controller must shield the quiet tenant: storm miss rate {:.3} \
         (quiet baseline {:.3}, {} storm completions)",
        storm_b_on.miss_rate(),
        quiet_b.miss_rate(),
        storm_b_on.requests
    );
    // the flash tenant pays its own bill at the gate
    assert!(
        on.phases[1][1].shed > 0,
        "controller arm must shed some of the flash crowd"
    );
    // counterfactual: on the identical storm, the open-loop arm hurts
    // the bystander more (misses + collateral sheds combined)
    assert!(
        storm_b_off.bad_rate() > storm_b_on.bad_rate(),
        "controller-off must be worse for the bystander: off {:.3} vs on {:.3} \
         (off: {} miss / {} shed / {} served; on: {} miss / {} shed / {} served)",
        storm_b_off.bad_rate(),
        storm_b_on.bad_rate(),
        storm_b_off.sla_miss,
        storm_b_off.shed,
        storm_b_off.requests,
        storm_b_on.sla_miss,
        storm_b_on.shed,
        storm_b_on.requests
    );
    // brownout recovery: clean post-storm windows decay the shed level
    // to zero well inside the 1.5 s recovery phase
    assert_eq!(
        on.final_shed_permille_t1, 0,
        "shed level must recover to 0 after the storm"
    );
    let recovery_b = on.phases[2][0];
    assert!(
        recovery_b.miss_rate() < 0.1,
        "post-storm the quiet tenant is clean again: {:.3}",
        recovery_b.miss_rate()
    );
}
