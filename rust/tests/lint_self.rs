//! Self-hosting acceptance: `flame lint` over this crate's own sources
//! must produce no findings beyond the committed baseline (which is
//! kept empty — fix findings, don't grandfather them), and the inferred
//! lock-acquisition graph must contain the documented *allowed* edges,
//! proving the analyzer actually sees the concurrency it guards.

use std::collections::BTreeSet;
use std::path::Path;

use flame::lint::source::LockClass;
use flame::lint::{apply_baseline, build_model, check, parse_baseline, scan_root};

fn analyze() -> flame::lint::Analysis {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let sources = scan_root(root).expect("scan crate sources");
    assert!(
        sources.iter().any(|(p, _)| p.ends_with("dso/coalescer.rs")),
        "scan_root must cover src/ (got {} files)",
        sources.len()
    );
    assert!(
        sources.iter().any(|(p, _)| p.ends_with("tests/lint_self.rs")),
        "scan_root must cover tests/"
    );
    check(&build_model(&sources))
}

#[test]
fn crate_is_clean_under_its_own_lint() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let analysis = analyze();
    let accepted: BTreeSet<String> = std::fs::read_to_string(root.join("lint_baseline.txt"))
        .map(|t| parse_baseline(&t))
        .unwrap_or_default();
    assert!(
        accepted.is_empty(),
        "the committed baseline must stay empty — fix findings instead:\n{accepted:?}"
    );
    let (_, fresh) = apply_baseline(&analysis, &accepted);
    let rendered: Vec<String> = fresh.iter().map(|f| f.render()).collect();
    assert!(
        fresh.is_empty(),
        "`flame lint` found non-baselined violations in the crate:\n{}",
        rendered.join("\n")
    );
}

#[test]
fn inferred_graph_contains_the_documented_flusher_edges() {
    let analysis = analyze();
    let field = |file: &str, name: &str| {
        analysis.edges.iter().any(|e| {
            let held_is_signal = matches!(
                &e.held,
                LockClass::Field { file: hf, field } if hf.ends_with(file) && field == "signal"
            );
            let acquired_matches = matches!(
                &e.acquired,
                LockClass::Field { file: af, field } if af.ends_with(file) && field == name
            );
            held_is_signal && acquired_matches
        })
    };
    // the flusher direction (signal held, slot/shard taken briefly) is
    // the allowed order — if these edges vanish the walker has gone
    // blind and the lock-order checker is vacuous
    assert!(
        field("dso/coalescer.rs", "slots"),
        "missing signal -> slots edge for the DSO flusher; edges: {:#?}",
        analysis.edges
    );
    assert!(
        field("pda/fetch_coalescer.rs", "shards"),
        "missing signal -> shards edge for the fetch flusher; edges: {:#?}",
        analysis.edges
    );
}
