//! Native CPU FKE integration (artifact-free): cross-variant score
//! identity, native-segmented vs solo-launch bit-exactness under random
//! coalescer packings, orchestrator-level waste accounting (native M
//! executed rows vs the PJRT-style per-history replay), and full-stack
//! wiring through `StackBuilder::build_from_backends` + the recorder.

use std::sync::atomic::Ordering;
use std::sync::{Arc, Barrier};

use flame::config::{CacheMode, DsoConfig, DsoMode, ModelConfig, StackConfig};
use flame::dso::{ComputeBackend, HistHandle, KernelStats, Orchestrator, SegmentBind, SimEngine};
use flame::fke::cpu::{CpuEngine, CpuEngineConfig, CpuModel};
use flame::fke::Variant;
use flame::manifest::testvec::max_abs_diff;
use flame::metrics::Recorder;
use flame::pda::StagingArena;
use flame::server::pipeline::StackBuilder;
use flame::util::propcheck;
use flame::workload::Request;

fn cfg() -> ModelConfig {
    ModelConfig {
        name: "cputest".into(),
        seq_len: 16,
        n_blocks: 2,
        layers_per_block: 2,
        d_model: 16,
        n_heads: 2,
        n_tasks: 3,
        m_profiles: vec![4, 8],
        native_m: 8,
    }
}

fn inputs(c: &ModelConfig, m: usize, salt: u64) -> (Vec<f32>, Vec<f32>) {
    let hist: Vec<f32> = (0..c.seq_len * c.d_model)
        .map(|i| (((i as u64 + salt) * 31 % 113) as f32 / 113.0) - 0.5)
        .collect();
    let cands: Vec<f32> = (0..m * c.d_model)
        .map(|i| (((i as u64 + salt) * 17 % 127) as f32 / 127.0) - 0.5)
        .collect();
    (hist, cands)
}

fn engines(c: &ModelConfig, m: usize, threads: usize) -> [CpuEngine; 3] {
    let model = CpuModel::new(c, 42).unwrap();
    Variant::all().map(|variant| {
        CpuEngine::new(Arc::clone(&model), m, &CpuEngineConfig { variant, threads })
    })
}

/// Satellite acceptance: fused and api are bit-exact (the mask schedule
/// only removes exact-zero contributions); naive is held to 1e-5 — its
/// per-element accumulation order is engineered to match too, but the
/// tolerance documents the allowed reassociation budget for a
/// mechanically-exported graph.
#[test]
fn cross_variant_scores_agree() {
    let c = cfg();
    let [naive, api, fused] = engines(&c, 8, 2);
    for salt in [1u64, 29, 77] {
        let (hist, cands) = inputs(&c, 8, salt);
        let sn = naive.run(&hist, &cands).unwrap();
        let sa = api.run(&hist, &cands).unwrap();
        let sf = fused.run(&hist, &cands).unwrap();
        assert_eq!(sa, sf, "salt {salt}: fused must be bit-exact with api");
        let diff = max_abs_diff(&sn, &sa);
        assert!(diff < 1e-5, "salt {salt}: naive vs api diff {diff}");
        assert!(sn.iter().all(|s| s.is_finite() && (0.0..=1.0).contains(s)));
    }
}

/// Satellite acceptance: for any coalescer packing (random segment
/// sizes, random histories), a packed mixed batch scores every row
/// bit-identically to that row's own solo launch — in every variant.
#[test]
fn prop_native_segmented_matches_solo_launches() {
    let c = cfg();
    let engines = engines(&c, 8, 2);
    propcheck::check("cpu segmented == solo", 12, |g| {
        let n_seg = g.usize_in(1, 4);
        // random partition of the 8-row profile into n_seg segments
        let mut rows = Vec::with_capacity(n_seg);
        let mut remaining = 8usize;
        for s in 0..n_seg - 1 {
            let left = n_seg - 1 - s; // rows the remaining segments need
            let take = g.usize_in(1, remaining - left + 1);
            rows.push(take);
            remaining -= take;
        }
        rows.push(remaining);

        let salts: Vec<u64> = (0..n_seg).map(|_| g.u64_below(1 << 20)).collect();
        for e in &engines {
            let hists: Vec<_> = salts
                .iter()
                .map(|&s| e.upload_hist(&inputs(&c, 8, s).0).unwrap())
                .collect();
            let segs: Vec<Vec<f32>> = salts
                .iter()
                .zip(&rows)
                .map(|(&s, &r)| inputs(&c, r, s ^ 0xC0FFEE).1)
                .collect();
            let mut packed = Vec::new();
            for seg in &segs {
                packed.extend_from_slice(seg);
            }
            let binds: Vec<SegmentBind<'_>> = hists
                .iter()
                .zip(&rows)
                .map(|(h, &r)| SegmentBind { hist: h, rows: r })
                .collect();
            let out = e.run_segmented(&binds, &packed).unwrap();
            if e.executed_rows_for(n_seg) != 8 {
                return Err(format!(
                    "native backend must execute m rows once, got {}",
                    e.executed_rows_for(n_seg)
                ));
            }

            // each segment alone, padded to the profile with its own
            // last row repeated (what the orchestrator's pad does)
            let mut off = 0usize;
            for (i, (seg, &r)) in segs.iter().zip(&rows).enumerate() {
                let mut solo = seg.clone();
                let last = &seg[(r - 1) * c.d_model..r * c.d_model];
                for _ in 0..8 - r {
                    solo.extend_from_slice(last);
                }
                let sref = e
                    .run_segmented(&[SegmentBind { hist: &hists[i], rows: 8 }], &solo)
                    .unwrap();
                let got = &out[off * c.n_tasks..(off + r) * c.n_tasks];
                if got != &sref[..r * c.n_tasks] {
                    return Err(format!(
                        "{}: segment {i} (rows {r}) diverged from its solo launch",
                        e.label()
                    ));
                }
                off += r;
            }
        }
        Ok(())
    });
}

/// A PJRT-style backend standing in for the per-history replay
/// emulation: scores are exact (delegated to `SimEngine`), but a packed
/// batch of S segments costs `m * S` executed rows.
struct ReplayEngine(SimEngine);

impl ComputeBackend for ReplayEngine {
    fn m(&self) -> usize {
        self.0.m()
    }
    fn n_tasks(&self) -> usize {
        self.0.n_tasks()
    }
    fn d_model(&self) -> usize {
        self.0.d_model()
    }
    fn hist_len(&self) -> usize {
        self.0.hist_len()
    }
    fn upload_hist(&self, hist: &[f32]) -> flame::Result<HistHandle> {
        self.0.upload_hist(hist)
    }
    fn run_segmented(
        &self,
        segments: &[SegmentBind<'_>],
        cands: &[f32],
    ) -> flame::Result<Vec<f32>> {
        self.0.run_segmented(segments, cands)
    }
    fn label(&self) -> String {
        format!("replay/{}", self.0.label())
    }
    fn executed_rows_for(&self, segments: usize) -> usize {
        self.0.m() * segments.max(1)
    }
}

/// Satellite acceptance: the recorder/orchestrator waste metrics count
/// M executed rows for a natively segmented backend (CpuEngine) but the
/// full M × segments replay cost for an emulating backend — on the same
/// coalesced workload.
#[test]
fn coalesce_waste_accounting_native_vs_replay() {
    const N: usize = 8; // concurrent 1-row requests onto an 8-profile
    let c = cfg();
    let dso = DsoConfig {
        mode: DsoMode::Explicit,
        executors_per_profile: 2,
        queue_capacity: 1024,
        coalesce: true,
        coalesce_wait_us: 300_000,
    };
    let model = CpuModel::new(&c, 42).unwrap();
    let profile_cfg = ModelConfig { m_profiles: vec![8], native_m: 8, ..c.clone() };
    let cpu_engine = Arc::new(CpuEngine::new(
        Arc::clone(&model),
        8,
        &CpuEngineConfig { variant: Variant::Fused, threads: 1 },
    ));
    let drive = |backend: Arc<dyn ComputeBackend>| -> (Arc<Orchestrator>, Vec<Vec<f32>>) {
        let orch =
            Arc::new(Orchestrator::from_backends(vec![backend], &dso, None).unwrap());
        let barrier = Arc::new(Barrier::new(N));
        let scores: Vec<Vec<f32>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..N)
                .map(|i| {
                    let orch = Arc::clone(&orch);
                    let barrier = Arc::clone(&barrier);
                    let c = &profile_cfg;
                    s.spawn(move || {
                        let (hist, cands) = inputs(c, 1, i as u64);
                        barrier.wait();
                        orch.submit_slice(&hist, &cands, 1).unwrap().scores
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        (orch, scores)
    };

    // native CPU backend: executed rows == launches * m, period
    let (cpu_orch, cpu_scores) = drive(Arc::clone(&cpu_engine) as Arc<dyn ComputeBackend>);
    let cpu_stats = cpu_orch.coalesce_stats();
    assert!(cpu_stats.multi_request_batches >= 1, "no packing happened: {cpu_stats:?}");
    let launches = cpu_engine.kernel_stats().launches;
    let cpu_executed = cpu_orch.executed_rows_total.load(Ordering::Relaxed);
    assert_eq!(
        cpu_executed,
        launches * 8,
        "natively segmented backend must execute m rows per launch, not m * segments"
    );
    assert!(cpu_executed < (N * 8) as u64, "packing must beat solo launches");

    // replay-emulating backend on the same workload: every packed
    // launch is charged m * segments — total is always N requests * m
    let (replay_orch, replay_scores) =
        drive(Arc::new(ReplayEngine(SimEngine::new(8, c.seq_len, c.d_model, c.n_tasks))));
    let replay_executed = replay_orch.executed_rows_total.load(Ordering::Relaxed);
    assert_eq!(
        replay_executed,
        (N * 8) as u64,
        "replay emulation must be charged per-history, segments notwithstanding"
    );
    assert!(cpu_executed < replay_executed);

    // and the cpu waste metric now reflects real savings: padded rows
    // are launches * 8 - N real rows, a strict subset of executed rows
    assert!(cpu_orch.waste_fraction() < 1.0);
    assert!((replay_scores.len(), cpu_scores.len()) == (N, N));

    // score correctness for the cpu path: every request's row equals a
    // solo submit through a fresh non-coalescing orchestrator
    let baseline = Orchestrator::from_backends(
        vec![Arc::new(CpuEngine::new(
            Arc::clone(&model),
            8,
            &CpuEngineConfig { variant: Variant::Fused, threads: 1 },
        )) as Arc<dyn ComputeBackend>],
        &DsoConfig::default(),
        None,
    )
    .unwrap();
    for (i, scores) in cpu_scores.iter().enumerate() {
        let (hist, cands) = inputs(&profile_cfg, 1, i as u64);
        let expected = baseline.submit_slice(&hist, &cands, 1).unwrap().scores;
        assert_eq!(scores, &expected, "request {i} diverged under coalescing");
    }
}

/// Full-stack wiring: a serving stack over CPU engines scores requests
/// end to end on a bare checkout, and the engines' FLOP/tile counters
/// reach the stack's shared recorder and the orchestrator aggregate.
#[test]
fn cpu_stack_serves_and_reports_kernel_stats() {
    let c = cfg();
    let mut stack_cfg = StackConfig::default();
    stack_cfg.pda.cache_mode = CacheMode::Sync;
    stack_cfg.pda.numa_binding = false;
    let recorder = Arc::new(Recorder::new());
    let model = CpuModel::new(&c, 42).unwrap();
    let backends = CpuEngine::profile_set(
        &model,
        &CpuEngineConfig { variant: Variant::Fused, threads: 2 },
        Some(Arc::clone(&recorder)),
    );
    let stack = StackBuilder::new("cputest", "fused", stack_cfg)
        .with_metrics(Arc::clone(&recorder))
        .build_from_backends(c.clone(), 7, backends)
        .expect("cpu stack");

    let req = Request {
        request_id: 1,
        user_id: 3,
        history: (0..10).collect(),
        candidates: (100..105).collect(), // m = 5 → split 4 + remainder
        ..Default::default()
    };
    let mut arena = StagingArena::new(stack.arena_capacity());
    let resp = stack.serve(&req, &mut arena).expect("serve");
    assert_eq!(resp.scores.len(), 5 * c.n_tasks);
    assert!(resp.scores.iter().all(|s| s.is_finite() && (0.0..=1.0).contains(s)));

    let ks: KernelStats = stack.orchestrator.kernel_stats();
    assert!(ks.launches >= 2, "split request must launch both profiles: {ks:?}");
    assert!(ks.flops > 0 && ks.tiles_visited > 0);
    assert!(ks.tile_skip_fraction() > 0.0, "fused variant must skip tiles: {ks:?}");
    let snap = stack.metrics.snapshot();
    assert_eq!(snap.fke_flops, ks.flops, "recorder mirror must match engine counters");
    assert_eq!(snap.fke_tiles_visited, ks.tiles_visited);
    assert_eq!(snap.fke_tiles_skipped, ks.tiles_skipped);
    // launch wall time was measured and recorded
    assert!(snap.compute_mean_ms >= 0.0);
}
