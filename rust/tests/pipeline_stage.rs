//! Decoupled two-stage pipeline over the artifact-free `SimEngine`
//! backend: score identity vs. the synchronous path under random
//! interleavings, arena-pool reuse safety, steady-state zero arena
//! growth, stage overlap, handoff backpressure, and the feature-miss
//! coalescer's round-trip savings — all on a bare checkout (no
//! artifacts, no PJRT).

use std::sync::{Arc, Barrier};
use std::time::Duration;

use flame::config::{CacheMode, ModelConfig, StackConfig};
use flame::dso::{ComputeBackend, SimEngine};
use flame::netsim::{Link, LinkConfig};
use flame::pda::StagingArena;
use flame::server::pipeline::StackBuilder;
use flame::server::ServingStack;
use flame::util::propcheck;
use flame::workload::Request;

const SEQ: usize = 16;
const D: usize = 8;
const TASKS: usize = 3;
const PROFILES: [usize; 2] = [4, 8];
const SEED: u64 = 77;

fn model_cfg() -> ModelConfig {
    ModelConfig {
        name: "sim".into(),
        seq_len: SEQ,
        n_blocks: 1,
        layers_per_block: 1,
        d_model: D,
        n_heads: 1,
        n_tasks: TASKS,
        m_profiles: PROFILES.to_vec(),
        native_m: PROFILES[PROFILES.len() - 1],
    }
}

fn fast_link() -> Arc<Link> {
    Arc::new(Link::new(LinkConfig {
        rtt: Duration::from_micros(200),
        bandwidth_bps: 1e9,
        jitter: 0.0,
        fail_rate: 0.0,
    }))
}

/// Build a sim-backed stack; `cfgmod` tweaks the config, `delay` is the
/// per-launch compute time, `link` the feature-store link.
fn sim_stack(
    cfgmod: impl FnOnce(&mut StackConfig),
    delay: Duration,
    link: Arc<Link>,
) -> Arc<ServingStack> {
    let mut cfg = StackConfig::default();
    cfg.pda.cache_mode = CacheMode::Sync;
    cfg.pda.numa_binding = false;
    cfgmod(&mut cfg);
    let backends: Vec<Arc<dyn ComputeBackend>> = PROFILES
        .iter()
        .map(|&m| {
            Arc::new(SimEngine::new(m, SEQ, D, TASKS).with_delay(delay))
                as Arc<dyn ComputeBackend>
        })
        .collect();
    Arc::new(
        StackBuilder::new("sim", "sim", cfg)
            .with_link(link)
            .build_from_backends(model_cfg(), SEED, backends)
            .expect("sim stack"),
    )
}

fn request(id: u64, m: usize, salt: u64) -> Request {
    let hist_len = (salt % (2 * SEQ as u64)) as usize; // short and over-long
    Request {
        request_id: id,
        user_id: salt % 100,
        history: (0..hist_len as u64).map(|i| salt.wrapping_mul(31) ^ i).collect(),
        candidates: (0..m as u64).map(|i| salt.wrapping_mul(17) ^ (i << 8)).collect(),
        ..Default::default()
    }
}

/// Acceptance criterion: for any interleaving of concurrent requests,
/// the decoupled pipeline (with both coalescers on) returns bit-identical
/// scores, in each request's own candidate order, to the synchronous
/// `serve` path. Features are deterministic per (seed, id) in sync cache
/// mode and the SimEngine scores are a pure per-row function, so any
/// divergence can only come from the pipeline mis-staging, mis-packing,
/// or recycling an arena too early.
#[test]
fn prop_pipelined_scores_bit_identical_to_sync() {
    let baseline = sim_stack(|_| {}, Duration::ZERO, fast_link());
    let pipelined = sim_stack(
        |c| {
            c.server.pipeline = true;
            c.server.feature_workers = 2;
            c.server.pipeline_workers = 2;
            c.server.handoff_capacity = 4;
            c.pda.fetch_coalesce = true;
            c.pda.fetch_wait_us = 300;
            c.dso.coalesce = true;
            c.dso.coalesce_wait_us = 500;
        },
        Duration::ZERO,
        fast_link(),
    );
    let handle = pipelined.spawn_pipeline();
    propcheck::check("pipelined == sync scores", 20, |g| {
        let n_req = g.usize_in(2, 7);
        let reqs: Vec<Request> = (0..n_req)
            .map(|i| request(i as u64, g.usize_in(1, 13), g.u64_below(1 << 30)))
            .collect();
        // expected: each request alone through the synchronous path
        let mut arena = StagingArena::new(baseline.arena_capacity());
        let expected: Vec<Vec<f32>> = reqs
            .iter()
            .map(|r| baseline.serve(r, &mut arena).unwrap().scores)
            .collect();
        // actual: all requests concurrently through the pipeline — the
        // barrier maximizes stage interleaving
        let barrier = Arc::new(Barrier::new(n_req));
        let got: Vec<Vec<f32>> = std::thread::scope(|s| {
            let handles: Vec<_> = reqs
                .iter()
                .map(|r| {
                    let handle = &handle;
                    let barrier = Arc::clone(&barrier);
                    s.spawn(move || {
                        barrier.wait();
                        handle.serve(r).unwrap().scores
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for (i, (e, a)) in expected.iter().zip(&got).enumerate() {
            if e != a {
                return Err(format!(
                    "request {i} (m={}) scores diverged through the pipeline",
                    reqs[i].m()
                ));
            }
        }
        Ok(())
    });
    handle.shutdown();
}

/// Arena-pool reuse-after-return safety: with a minimal pool, every
/// arena is recycled across requests; responses must stay correct and
/// every arena must come back to the pool.
#[test]
fn arena_pool_reuse_after_return_is_safe() {
    let baseline = sim_stack(|_| {}, Duration::ZERO, fast_link());
    let pipelined = sim_stack(
        |c| {
            c.server.pipeline = true;
            c.server.feature_workers = 1;
            c.server.pipeline_workers = 1;
            c.server.handoff_capacity = 1;
        },
        Duration::ZERO,
        fast_link(),
    );
    let handle = pipelined.spawn_pipeline();
    let total = handle.idle_arenas();
    assert_eq!(total, 3, "1 feature + 1 compute + 1 handoff slot");
    let mut arena = StagingArena::new(baseline.arena_capacity());
    for i in 0..32u64 {
        let req = request(i, 1 + (i as usize % 12), i.wrapping_mul(0x9E37) + 1);
        let expected = baseline.serve(&req, &mut arena).unwrap().scores;
        let got = handle.serve(&req).unwrap();
        assert_eq!(got.scores, expected, "request {i} corrupted by arena reuse");
    }
    // the response is sent before the arena returns; poll briefly
    let t0 = std::time::Instant::now();
    while handle.idle_arenas() < total && t0.elapsed() < Duration::from_secs(5) {
        std::thread::sleep(Duration::from_millis(2));
    }
    assert_eq!(handle.idle_arenas(), total, "an arena leaked out of the pool");
    handle.shutdown();
}

/// Satellite acceptance: arenas are sized from `arena_capacity()`, so a
/// steady-state run must never grow one — and the growth counter now
/// proves it through the recorder.
#[test]
fn steady_state_pipeline_has_zero_arena_growth() {
    let stack = sim_stack(
        |c| {
            c.server.pipeline = true;
            c.server.feature_workers = 2;
            c.server.pipeline_workers = 2;
        },
        Duration::ZERO,
        fast_link(),
    );
    let handle = stack.spawn_pipeline();
    let reqs: Vec<Request> =
        (0..64).map(|i| request(i, 1 + (i as usize % 8), i + 1)).collect();
    let report = handle.drive_closed_loop(&reqs, 4, Duration::from_secs(30));
    assert_eq!(report.completed, 64, "{report:?}");
    assert_eq!(
        stack.metrics.arena_growths(),
        0,
        "steady-state serving must never grow a staging arena"
    );
    // every pipelined request recorded its stage wait
    assert_eq!(stack.metrics.handoff.count(), 64);
    handle.shutdown();
}

/// The tentpole's point: with one worker per stage, request B's feature
/// work overlaps request A's engine launch, so total busy time across
/// the two stages exceeds wall time — impossible for the sequential
/// single-worker path.
#[test]
fn stages_overlap_under_concurrency() {
    let compute_delay = Duration::from_millis(50);
    let link = Arc::new(Link::new(LinkConfig {
        rtt: Duration::from_millis(15),
        bandwidth_bps: 1e9,
        jitter: 0.0,
        fail_rate: 0.0,
    }));
    let stack = sim_stack(
        |c| {
            c.server.pipeline = true;
            c.server.feature_workers = 1;
            c.server.pipeline_workers = 1;
            c.server.handoff_capacity = 2;
        },
        compute_delay,
        link,
    );
    let handle = stack.spawn_pipeline();
    // distinct candidate ids per request: every request pays a real
    // remote fetch, so the feature stage has genuine work to overlap
    let reqs: Vec<Request> = (0..6).map(|i| request(i, 4, (i + 1) * 1_000)).collect();
    let t0 = std::time::Instant::now();
    let report = handle.drive_closed_loop(&reqs, 3, Duration::from_secs(30));
    let elapsed_us = t0.elapsed().as_micros() as f64;
    assert_eq!(report.completed, 6, "{report:?}");
    let snap = stack.metrics.snapshot();
    let feature_busy_us = snap.feature_mean_ms * 1e3 * 6.0;
    let compute_busy_us = snap.compute_mean_ms * 1e3 * 6.0;
    assert!(
        feature_busy_us + compute_busy_us > elapsed_us,
        "no overlap: feature {feature_busy_us:.0}µs + compute {compute_busy_us:.0}µs \
         within wall {elapsed_us:.0}µs"
    );
    // every request's stage wait was recorded
    assert_eq!(stack.metrics.handoff.count(), 6);
    handle.shutdown();
}

/// Backpressure: a slow compute stage fills the handoff queue, stalls
/// the feature worker, and the bounded intake then sheds at admission —
/// while every admitted request still completes correctly.
#[test]
fn full_handoff_queue_sheds_at_intake() {
    let stack = sim_stack(
        |c| {
            c.server.pipeline = true;
            c.server.feature_workers = 1;
            c.server.pipeline_workers = 1;
            c.server.handoff_capacity = 1;
            c.dso.queue_capacity = 2; // intake bound
        },
        Duration::from_millis(60),
        fast_link(),
    );
    let handle = stack.spawn_pipeline();
    let mut accepted = Vec::new();
    let mut shed = 0usize;
    for i in 0..12u64 {
        match handle.submit(request(i, 2, i + 1)) {
            Ok(rx) => accepted.push(rx),
            Err(e) => {
                assert!(
                    matches!(e, flame::Error::Overloaded(_)),
                    "sheds must surface as Overloaded, got {e:?}"
                );
                shed += 1;
            }
        }
    }
    assert!(shed >= 1, "a 12-request burst into depth-5 pipeline must shed");
    assert!(!accepted.is_empty());
    for rx in accepted {
        let resp = rx.recv().expect("pipeline alive").expect("admitted request served");
        assert_eq!(resp.scores.len(), 2 * TASKS);
    }
    handle.shutdown();
}

/// Miss coalescer end to end: concurrent pipelined requests missing the
/// same hot candidates share remote multigets — fewer store round-trips
/// than requests, identical scores (already covered by the property
/// test; here we pin the query-count saving).
#[test]
fn fetch_coalescer_cuts_remote_queries_for_hot_candidates() {
    const WAVES: usize = 4;
    const PER_WAVE: usize = 6;
    let run = |coalesce: bool| -> u64 {
        let link = fast_link();
        let stack = sim_stack(
            |c| {
                c.server.pipeline = true;
                c.server.feature_workers = 4;
                c.server.pipeline_workers = 2;
                c.pda.fetch_coalesce = coalesce;
                c.pda.fetch_wait_us = 20_000;
                c.pda.cache_ttl_ms = 1; // keep hot ids missing
            },
            Duration::ZERO,
            Arc::clone(&link),
        );
        let handle = stack.spawn_pipeline();
        for wave in 0..WAVES as u64 {
            std::thread::sleep(Duration::from_millis(3)); // let the TTL lapse
            let barrier = Arc::new(Barrier::new(PER_WAVE));
            std::thread::scope(|s| {
                for i in 0..PER_WAVE as u64 {
                    let handle = &handle;
                    let barrier = Arc::clone(&barrier);
                    s.spawn(move || {
                        // same hot candidate set every time
                        let req = Request {
                            request_id: wave * 100 + i,
                            user_id: i,
                            history: vec![1, 2, 3],
                            candidates: vec![500, 501, 502, 503],
                            ..Default::default()
                        };
                        barrier.wait();
                        handle.serve(&req).unwrap();
                    });
                }
            });
        }
        handle.shutdown();
        link.queries_total()
    };
    let without = run(false);
    let with = run(true);
    assert!(
        with < without,
        "coalescing must cut remote queries: {with} vs {without}"
    );
    // ideal: one multiget per wave; allow slack for TTL-expiry raggedness
    assert!(
        with <= (WAVES * PER_WAVE) as u64 / 2,
        "expected ~1 query/wave, saw {with}"
    );
}

/// Shutdown-while-cancelled race: dropping the `PipelineHandle` while
/// the intake still holds a mix of live and already-expired jobs must
/// wake every reply channel with a typed result — served, `Cancelled`,
/// or `Shutdown` — and return every arena to the pool. A silently
/// dropped reply would hang the submitter forever.
#[test]
fn shutdown_with_cancelled_jobs_queued_wakes_every_reply() {
    let stack = sim_stack(
        |c| {
            c.server.pipeline = true;
            c.server.cancel = true;
            c.server.feature_workers = 1;
            c.server.pipeline_workers = 1;
            c.server.handoff_capacity = 1;
            c.dso.queue_capacity = 64;
        },
        Duration::from_millis(20),
        fast_link(),
    );
    let handle = stack.spawn_pipeline();
    // a slack blocker pins the compute stage, then a burst of doomed
    // jobs queues behind it with deadlines that expire while queued
    let blocker = handle
        .submit_with_deadline(request(0, 4, 1), Duration::from_secs(10))
        .expect("admit blocker");
    let doomed: Vec<_> = (1..=8u64)
        .map(|i| {
            handle
                .submit_with_deadline(request(i, 4, i + 1), Duration::from_millis(1))
                .expect("admit doomed")
        })
        .collect();
    std::thread::sleep(Duration::from_millis(10)); // let the deadlines lapse
    drop(handle); // shutdown drains both stages
    blocker.recv().expect("blocker reply must arrive").expect("blocker served");
    for (i, rx) in doomed.into_iter().enumerate() {
        let r = rx.recv().unwrap_or_else(|_| {
            panic!("doomed request {i} left hanging: reply channel dropped unresolved")
        });
        match r {
            Err(flame::Error::Cancelled(cause, _)) => {
                assert_eq!(cause, flame::cancel::CancelCause::Expired, "request {i}")
            }
            Err(flame::Error::Shutdown(_)) | Ok(_) => {} // lost the race to the purge
            Err(e) => panic!("doomed request {i}: unexpected error {e:?}"),
        }
    }
    assert!(
        stack.metrics.cancelled_total() >= 1,
        "expired queued jobs must hit the cancelled ledger"
    );
}

/// Explicit fires are honored even with `ServerConfig::cancel` off: the
/// token never self-expires, but a caller-side `cancel(Shutdown)` on a
/// queued job still resolves it with the typed cause, counted exactly
/// once in the recorder.
#[test]
fn explicit_fire_with_cancel_knob_off_still_resolves_typed() {
    let stack = sim_stack(
        |c| {
            c.server.pipeline = true; // knob off: c.server.cancel stays false
            c.server.feature_workers = 1;
            c.server.pipeline_workers = 1;
            c.server.handoff_capacity = 1;
        },
        Duration::from_millis(30),
        fast_link(),
    );
    let handle = stack.spawn_pipeline();
    let total = handle.total_arenas();
    let blocker = handle
        .submit_with_deadline(request(0, 4, 1), Duration::from_secs(10))
        .expect("admit blocker");
    let (rx, token) = handle
        .submit_with_cancel(request(1, 4, 2), Duration::from_millis(1))
        .expect("admit victim");
    // the 1ms "deadline" must NOT fire on its own — the knob is off
    std::thread::sleep(Duration::from_millis(5));
    assert!(!token.is_cancelled(), "deadline-free token self-expired");
    assert!(token.cancel(flame::cancel::CancelCause::Shutdown), "first fire wins");
    match rx.recv().expect("reply must arrive") {
        Err(flame::Error::Cancelled(cause, _)) => {
            assert_eq!(cause, flame::cancel::CancelCause::Shutdown)
        }
        other => panic!("expected typed Cancelled, got {other:?}"),
    }
    blocker.recv().expect("pipeline alive").expect("blocker served");
    assert_eq!(
        stack.metrics.cancelled_by_cause(flame::cancel::CancelCause::Shutdown),
        1,
        "explicit fire must be counted exactly once"
    );
    let t0 = std::time::Instant::now();
    while handle.idle_arenas() < total && t0.elapsed() < Duration::from_secs(5) {
        std::thread::sleep(Duration::from_millis(2));
    }
    assert_eq!(handle.idle_arenas(), total, "an arena leaked on the cancel path");
    handle.shutdown();
}

/// Satellite: deadline-closest-first intake. With
/// `ServerConfig::deadline_first` on, a tight-deadline request submitted
/// *after* a slack one overtakes it in the intake queue while the single
/// feature worker is busy — FIFO would serve the slack request first.
#[test]
fn deadline_first_intake_lets_tight_deadline_overtake() {
    // slow feature link: the blocker pins the only feature worker for a
    // full remote round-trip, guaranteeing both probe requests are
    // queued together when the worker next pops
    let link = Arc::new(Link::new(LinkConfig {
        rtt: Duration::from_millis(30),
        bandwidth_bps: 1e9,
        jitter: 0.0,
        fail_rate: 0.0,
    }));
    let stack = sim_stack(
        |c| {
            c.server.pipeline = true;
            c.server.feature_workers = 1;
            c.server.pipeline_workers = 1;
            c.server.handoff_capacity = 1;
            c.server.deadline_first = true;
        },
        Duration::from_millis(1),
        link,
    );
    let handle = stack.spawn_pipeline();

    let blocker = handle.submit(request(0, 4, 1)).expect("admit blocker");
    std::thread::sleep(Duration::from_millis(5));
    // enqueued in this order; deadline order is the reverse
    let slack = handle
        .submit_with_deadline(request(1, 4, 2), Duration::from_secs(10))
        .expect("admit slack");
    let tight = handle
        .submit_with_deadline(request(2, 4, 3), Duration::from_millis(5))
        .expect("admit tight");

    let order = Arc::new(std::sync::Mutex::new(Vec::new()));
    std::thread::scope(|s| {
        for (label, rx) in [("slack", slack), ("tight", tight)] {
            let order = Arc::clone(&order);
            s.spawn(move || {
                rx.recv().expect("pipeline alive").expect("served");
                order.lock().unwrap().push(label);
            });
        }
    });
    blocker.recv().expect("pipeline alive").expect("served");
    handle.shutdown();
    let order = order.lock().unwrap().clone();
    assert_eq!(order, vec!["tight", "slack"], "nearest deadline must pop first");
}
