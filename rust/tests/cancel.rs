//! End-to-end deadline propagation and cooperative cancellation
//! (tentpole acceptance): a seeded flash crowd at ~2x capacity with
//! tight deadlines, driven through the staged pipeline over the
//! artifact-free `SimEngine` backend. The cancellation arm must beat
//! the no-cancel arm on goodput (responses inside their budget), every
//! cancelled request must resolve with its typed cause, the recorder's
//! cause ledger must match the observed errors exactly, and nothing —
//! arenas, single-flight fetch tickets — may leak.

use std::sync::Arc;
use std::time::Duration;

use flame::cancel::{CancelCause, N_CAUSES};
use flame::config::{CacheMode, ModelConfig, StackConfig};
use flame::dso::{ComputeBackend, SimEngine};
use flame::netsim::{Link, LinkConfig};
use flame::server::pipeline::StackBuilder;
use flame::server::ServingStack;
use flame::workload::Request;

const SEQ: usize = 16;
const D: usize = 8;
const TASKS: usize = 3;
const PROFILES: [usize; 2] = [4, 8];
const SEED: u64 = 77;

/// Per-launch compute time: with one executor on the m=4 profile the
/// backlog from the flash crowd is deterministic and serial.
const COMPUTE: Duration = Duration::from_millis(4);
const DOOMED: u64 = 40; // flash crowd, 25 ms budgets — most cannot make it
const FOLLOW_UPS: u64 = 20; // arrive behind the crowd, 100 ms budgets
const DOOMED_BUDGET: Duration = Duration::from_millis(25);
const FOLLOW_UP_BUDGET: Duration = Duration::from_millis(100);

fn model_cfg() -> ModelConfig {
    ModelConfig {
        name: "sim".into(),
        seq_len: SEQ,
        n_blocks: 1,
        layers_per_block: 1,
        d_model: D,
        n_heads: 1,
        n_tasks: TASKS,
        m_profiles: PROFILES.to_vec(),
        native_m: PROFILES[PROFILES.len() - 1],
    }
}

fn sim_stack(cancel: bool) -> Arc<ServingStack> {
    let mut cfg = StackConfig::default();
    cfg.pda.cache_mode = CacheMode::Sync;
    cfg.pda.numa_binding = false;
    cfg.pda.fetch_coalesce = true; // exercise the rider-abandon path too
    cfg.server.pipeline = true;
    cfg.server.cancel = cancel;
    cfg.server.feature_workers = 1;
    cfg.server.pipeline_workers = 1;
    cfg.server.handoff_capacity = 4;
    cfg.dso.queue_capacity = 128; // admit the whole crowd — no shedding noise
    let link = Arc::new(Link::new(LinkConfig {
        rtt: Duration::from_micros(200),
        bandwidth_bps: 1e9,
        jitter: 0.0,
        fail_rate: 0.0,
    }));
    let backends: Vec<Arc<dyn ComputeBackend>> = PROFILES
        .iter()
        .map(|&m| {
            Arc::new(SimEngine::new(m, SEQ, D, TASKS).with_delay(COMPUTE))
                as Arc<dyn ComputeBackend>
        })
        .collect();
    Arc::new(
        StackBuilder::new("sim", "sim", cfg)
            .with_link(link)
            .build_from_backends(model_cfg(), SEED, backends)
            .expect("sim stack"),
    )
}

fn request(id: u64) -> Request {
    Request {
        request_id: id,
        user_id: id % 7,
        history: (0..8u64).map(|i| id.wrapping_mul(31) ^ i).collect(),
        candidates: (0..4u64).map(|i| id.wrapping_mul(17) ^ (i << 8)).collect(),
        ..Default::default()
    }
}

struct ArmOutcome {
    goodput: usize,
    /// Errors observed on reply channels, bucketed by cause index.
    cancelled_errs: [u64; N_CAUSES],
    other_errs: usize,
}

/// Drive one arm: the flash crowd, then the follow-ups, all on the
/// pipeline's submit path with explicit budgets. Goodput counts a
/// response that arrived inside its own budget.
fn drive_arm(stack: &Arc<ServingStack>) -> ArmOutcome {
    let handle = stack.spawn_pipeline();
    let total_arenas = handle.total_arenas();
    let mut pending: Vec<(std::sync::mpsc::Receiver<_>, Duration)> = Vec::new();
    for i in 0..DOOMED {
        let rx = handle
            .submit_with_deadline(request(i), DOOMED_BUDGET)
            .expect("crowd admitted — queue sized for it");
        pending.push((rx, DOOMED_BUDGET));
    }
    for i in 0..FOLLOW_UPS {
        let rx = handle
            .submit_with_deadline(request(DOOMED + i), FOLLOW_UP_BUDGET)
            .expect("follow-up admitted");
        pending.push((rx, FOLLOW_UP_BUDGET));
    }
    let mut out =
        ArmOutcome { goodput: 0, cancelled_errs: [0; N_CAUSES], other_errs: 0 };
    for (rx, budget) in pending {
        match rx.recv().expect("pipeline alive: every request must resolve") {
            Ok(resp) => {
                if Duration::from_micros(resp.overall_us) <= budget {
                    out.goodput += 1;
                }
            }
            Err(flame::Error::Cancelled(cause, _stage)) => {
                out.cancelled_errs[cause.index()] += 1;
            }
            Err(_) => out.other_errs += 1,
        }
    }
    // drain: nothing left in flight, every arena home, no fetch ticket
    // stranded in the single-flight tables
    let t0 = std::time::Instant::now();
    while handle.idle_arenas() < total_arenas && t0.elapsed() < Duration::from_secs(10) {
        std::thread::sleep(Duration::from_millis(2));
    }
    assert_eq!(
        handle.idle_arenas(),
        total_arenas,
        "an arena leaked somewhere on this arm's serve/cancel paths"
    );
    assert_eq!(
        stack.query.fetch_inflight(),
        0,
        "a single-flight fetch ticket leaked"
    );
    handle.shutdown();
    out
}

#[test]
fn flash_crowd_cancellation_beats_no_cancel_on_goodput() {
    let no_cancel_stack = sim_stack(false);
    let no_cancel = drive_arm(&no_cancel_stack);
    let cancel_stack = sim_stack(true);
    let cancel = drive_arm(&cancel_stack);

    // --- headline: cancellation turns doomed work into goodput
    assert!(
        cancel.goodput > no_cancel.goodput,
        "cancellation arm must beat no-cancel on goodput: {} vs {}",
        cancel.goodput,
        no_cancel.goodput
    );
    // the no-cancel arm must not cancel anything (admitted => completed)
    assert_eq!(
        no_cancel_stack.metrics.cancelled_total(),
        0,
        "no-cancel arm must run every admitted request to completion"
    );
    assert_eq!(no_cancel.cancelled_errs, [0; N_CAUSES]);
    assert_eq!(no_cancel.other_errs, 0, "no-cancel arm saw non-cancel errors");
    assert_eq!(cancel.other_errs, 0, "cancel arm saw non-cancel errors");

    // --- exact accounting: every typed error is in the ledger, every
    // ledger entry produced a typed error (fires : counts = 1 : 1)
    let m = cancel_stack.metrics.cancelled_matrix();
    for (c, &seen) in cancel.cancelled_errs.iter().enumerate() {
        let cause = CancelCause::from_index(c).expect("dense cause index");
        let recorded: u64 = m[c].iter().sum();
        assert_eq!(
            recorded,
            seen,
            "cause {:?}: recorder says {recorded}, reply channels saw {seen}",
            cause
        );
    }
    assert_eq!(
        cancel_stack.metrics.cancelled_total(),
        cancel.cancelled_errs.iter().sum::<u64>(),
        "ledger total must equal observed typed errors"
    );
    // the flash crowd really was doomed: the cancel arm dropped a
    // meaningful share of it, and saved compute is accounted
    assert!(
        cancel_stack.metrics.cancelled_by_cause(CancelCause::Expired) >= DOOMED / 4,
        "expected a large expired cohort, ledger: {m:?}"
    );
    assert!(
        cancel_stack.metrics.cancelled_saved_pairs() > 0,
        "dropped requests must report saved compute"
    );
}

/// A client that vanishes mid-request (`ClientGone` fired by its front)
/// resolves with the typed cause and is counted once — even though the
/// stack-side deadline never expires.
#[test]
fn client_gone_fire_resolves_and_counts_once() {
    let stack = sim_stack(true);
    let handle = stack.spawn_pipeline();
    // blocker pins the single compute submitter
    let blocker = handle
        .submit_with_deadline(request(0), Duration::from_secs(10))
        .expect("admit blocker");
    let (rx, token) = handle
        .submit_with_cancel(request(1), Duration::from_secs(10))
        .expect("admit victim");
    token.cancel(CancelCause::ClientGone);
    match rx.recv().expect("reply must arrive") {
        Err(flame::Error::Cancelled(cause, _)) => assert_eq!(cause, CancelCause::ClientGone),
        other => panic!("expected typed Cancelled(ClientGone), got {other:?}"),
    }
    blocker.recv().expect("pipeline alive").expect("blocker served");
    assert_eq!(stack.metrics.cancelled_by_cause(CancelCause::ClientGone), 1);
    assert_eq!(stack.metrics.cancelled_total(), 1, "exactly one drop in the ledger");
    handle.shutdown();
}
