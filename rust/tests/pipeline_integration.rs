//! Full-stack pipeline integration (tiny scenario): feature stage +
//! compute stage through `ServingStack`, worker pool + request queue,
//! metrics accounting, and the ablation arms behaving directionally.

use std::sync::Arc;
use std::time::Duration;

use flame::batching::RequestQueue;
use flame::config::{CacheMode, DsoMode, StackConfig, WorkloadConfig};
use flame::manifest::Manifest;
use flame::pda::StagingArena;
use flame::runtime::Runtime;
use flame::server::pipeline::StackBuilder;
use flame::workload::{Generator, Request};

fn build(cfgmod: impl FnOnce(&mut StackConfig)) -> Option<Arc<flame::server::ServingStack>> {
    let manifest = Manifest::load("artifacts").ok()?;
    if !manifest.scenarios.contains_key("tiny") {
        eprintln!("skipping: artifacts/tiny not built");
        return None;
    }
    let rt = match Runtime::new() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping: PJRT runtime unavailable ({e})");
            return None;
        }
    };
    let mut cfg = StackConfig::default();
    cfg.pda.cache_mode = CacheMode::Sync;
    cfg.server.pipeline_workers = 2;
    cfgmod(&mut cfg);
    let stack = StackBuilder::new("tiny", "fused", cfg).build(&rt, &manifest).ok()?;
    Some(Arc::new(stack))
}

fn gen_requests(n: usize, stack: &flame::server::ServingStack) -> Vec<Request> {
    let wl = WorkloadConfig {
        catalog_size: 5_000,
        zipf_theta: 1.0,
        n_users: 200,
        candidate_mix: WorkloadConfig::uniform_mix(stack.orchestrator.profiles()),
        arrival_rate: None,
        seed: 11,
    };
    let mut g = Generator::new(&wl, stack.model_cfg.seq_len);
    g.batch(n)
}

#[test]
fn serve_returns_scores_and_records_metrics() {
    let Some(stack) = build(|_| {}) else { return };
    let reqs = gen_requests(8, &stack);
    let mut arena = StagingArena::new(1 << 16);
    for r in &reqs {
        let resp = stack.serve(r, &mut arena).expect("serve");
        assert_eq!(resp.scores.len(), r.m() * stack.model_cfg.n_tasks);
        assert!(resp.scores.iter().all(|s| (0.0..=1.0).contains(s)));
        assert!(resp.overall_us >= resp.feature_us);
    }
    let snap = stack.metrics.snapshot();
    assert_eq!(snap.requests, 8);
    assert_eq!(snap.pairs as usize, reqs.iter().map(|r| r.m()).sum::<usize>());
    assert!(snap.overall_mean_ms > 0.0);
    assert!(snap.compute_mean_ms > 0.0);
}

#[test]
fn serve_is_deterministic_for_a_request() {
    let Some(stack) = build(|_| {}) else { return };
    let reqs = gen_requests(1, &stack);
    let mut arena = StagingArena::new(1 << 16);
    let a = stack.serve(&reqs[0], &mut arena).unwrap();
    let b = stack.serve(&reqs[0], &mut arena).unwrap();
    assert_eq!(a.scores, b.scores, "same request, same features -> same scores");
}

#[test]
fn worker_pool_drains_queue() {
    let Some(stack) = build(|_| {}) else { return };
    let reqs = gen_requests(16, &stack);
    let queue = RequestQueue::new(64);
    let workers = stack.spawn_workers(Arc::clone(&queue), 2);
    for r in reqs {
        queue.push(r).unwrap();
    }
    // wait for drain
    let t0 = std::time::Instant::now();
    while stack.metrics.requests() < 16 && t0.elapsed() < Duration::from_secs(60) {
        std::thread::sleep(Duration::from_millis(10));
    }
    queue.close();
    for w in workers {
        w.join().unwrap();
    }
    assert_eq!(stack.metrics.requests(), 16);
    assert_eq!(stack.metrics.dropped(), 0);
    // queueing delay was recorded
    assert!(stack.metrics.queueing.count() >= 16);
}

#[test]
fn short_history_padded_long_history_truncated() {
    let Some(stack) = build(|_| {}) else { return };
    let mut arena = StagingArena::new(1 << 16);
    let l = stack.model_cfg.seq_len;
    // short history
    let r1 = Request {
        request_id: 1,
        user_id: 0,
        history: vec![5; l / 2],
        candidates: vec![1, 2, 3, 4],
        ..Default::default()
    };
    let resp1 = stack.serve(&r1, &mut arena).expect("short history");
    assert_eq!(resp1.scores.len(), 4 * stack.model_cfg.n_tasks);
    // over-long history
    let r2 = Request {
        request_id: 2,
        user_id: 0,
        history: vec![5; l * 2],
        candidates: vec![1, 2, 3, 4],
        ..Default::default()
    };
    let resp2 = stack.serve(&r2, &mut arena).expect("long history");
    assert_eq!(resp2.scores.len(), 4 * stack.model_cfg.n_tasks);
}

#[test]
fn cache_off_pulls_more_network_than_sync() {
    let Some(off) = build(|c| c.pda.cache_mode = CacheMode::Off) else { return };
    let Some(sync) = build(|c| c.pda.cache_mode = CacheMode::Sync) else { return };
    let mut arena = StagingArena::new(1 << 16);
    for stack in [&off, &sync] {
        let reqs = gen_requests(24, stack);
        for r in &reqs {
            stack.serve(r, &mut arena).unwrap();
        }
    }
    let b_off = off.link.bytes_total();
    let b_sync = sync.link.bytes_total();
    assert!(
        b_sync < b_off,
        "sync cache should cut network bytes: {b_sync} vs {b_off}"
    );
}

#[test]
fn implicit_dso_executes_more_rows() {
    let Some(ex) = build(|c| c.dso.mode = DsoMode::Explicit) else { return };
    let Some(im) = build(|c| c.dso.mode = DsoMode::ImplicitPad) else { return };
    let mut arena = StagingArena::new(1 << 16);
    for stack in [&ex, &im] {
        let reqs = gen_requests(12, stack);
        for r in &reqs {
            stack.serve(r, &mut arena).unwrap();
        }
    }
    let rows_ex = ex.orchestrator.executed_rows_total.load(std::sync::atomic::Ordering::Relaxed);
    let rows_im = im.orchestrator.executed_rows_total.load(std::sync::atomic::Ordering::Relaxed);
    assert!(rows_ex < rows_im, "explicit {rows_ex} rows vs implicit {rows_im}");
}
