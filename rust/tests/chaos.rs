//! Chaos suite: seeded fault storms through the artifact-free sim
//! backends, proving the crate-wide invariant **no request is ever
//! lost** — every submission returns a response or a typed error, under
//! any fault plan — plus the degradation-ladder and recovery contracts:
//!
//! * a feature-store outage degrades to stale/default features
//!   (`ServeQuality::StaleFeatures`), never a failed request;
//! * an over-budget request serves a truncated candidate prefix
//!   (`ServeQuality::TruncatedCandidates`), never a rejection;
//! * a browned-out replica is routed around by a hedged re-dispatch;
//! * a crash window is absorbed by retry-with-backoff, and post-storm
//!   throughput returns to within 10% of pre-storm;
//! * supervised workers survive injected panics (in-flight requests
//!   fail with `Error::WorkerPanic`, the worker keeps draining), and
//!   the recorder's counters match what the plan actually injected.
//!
//! Everything here runs on a bare checkout — no artifacts, no PJRT.

use std::sync::Arc;
use std::time::{Duration, Instant};

use flame::chaos::{FaultPlan, ServeQuality};
use flame::cluster::{
    ClusterConfig, ClusterRouter, ReplicaBackend, RoutePolicy, SimConfig, SimReplica,
};
use flame::config::{CacheMode, ModelConfig, StackConfig};
use flame::dso::{ComputeBackend, SimEngine};
use flame::error::Error;
use flame::netsim::{Link, LinkConfig};
use flame::pda::StagingArena;
use flame::server::pipeline::StackBuilder;
use flame::server::ServingStack;
use flame::workload::Request;

const SEQ: usize = 16;
const D: usize = 8;
const TASKS: usize = 3;
const PROFILES: [usize; 2] = [4, 8];
const SEED: u64 = 77;

fn model_cfg() -> ModelConfig {
    ModelConfig {
        name: "sim".into(),
        seq_len: SEQ,
        n_blocks: 1,
        layers_per_block: 1,
        d_model: D,
        n_heads: 1,
        n_tasks: TASKS,
        m_profiles: PROFILES.to_vec(),
        native_m: PROFILES[PROFILES.len() - 1],
    }
}

fn fast_link() -> Arc<Link> {
    Arc::new(Link::new(LinkConfig {
        rtt: Duration::from_micros(200),
        bandwidth_bps: 1e9,
        jitter: 0.0,
        fail_rate: 0.0,
    }))
}

/// Sim-engine serving stack; `cfgmod` tweaks the config, `delay` is the
/// per-launch compute time.
fn sim_stack(cfgmod: impl FnOnce(&mut StackConfig), delay: Duration) -> Arc<ServingStack> {
    let mut cfg = StackConfig::default();
    cfg.pda.cache_mode = CacheMode::Sync;
    cfg.pda.numa_binding = false;
    cfgmod(&mut cfg);
    let backends: Vec<Arc<dyn ComputeBackend>> = PROFILES
        .iter()
        .map(|&m| {
            Arc::new(SimEngine::new(m, SEQ, D, TASKS).with_delay(delay))
                as Arc<dyn ComputeBackend>
        })
        .collect();
    Arc::new(
        StackBuilder::new("sim", "sim", cfg)
            .with_link(fast_link())
            .build_from_backends(model_cfg(), SEED, backends)
            .expect("sim stack"),
    )
}

/// Cluster of sim replicas; returns the sims (for chaos arming by
/// cluster index) and the router.
fn sim_cluster(
    n: usize,
    sim: SimConfig,
    cfgmod: impl FnOnce(&mut ClusterConfig),
) -> (Vec<Arc<SimReplica>>, Arc<ClusterRouter>) {
    let sims: Vec<Arc<SimReplica>> =
        (0..n).map(|_| Arc::new(SimReplica::new(sim.clone()))).collect();
    let backends: Vec<Arc<dyn ReplicaBackend>> =
        sims.iter().map(|s| Arc::clone(s) as Arc<dyn ReplicaBackend>).collect();
    let mut cfg = ClusterConfig {
        policy: RoutePolicy::RoundRobin,
        slots_per_replica: sim.slots,
        ..ClusterConfig::default()
    };
    cfgmod(&mut cfg);
    (sims.clone(), Arc::new(ClusterRouter::new(backends, cfg).unwrap()))
}

fn req(id: u64, user: u64, m: usize) -> Request {
    Request {
        request_id: id,
        user_id: user,
        history: (0..8u64).map(|i| user.wrapping_mul(31) ^ i).collect(),
        // unique per (id) so feature fetches stay cold and every
        // request really exercises the remote store
        candidates: (0..m as u64).map(|i| id.wrapping_mul(1_009) + i).collect(),
        ..Default::default()
    }
}

// ---------------------------------------------------------------------
// Ladder rung 1: store outage → stale/default features, full response.
// ---------------------------------------------------------------------

#[test]
fn store_outage_degrades_to_stale_features_not_errors() {
    let stack = sim_stack(|_| {}, Duration::ZERO);
    let plan = Arc::new(FaultPlan::parse("store_error:p=1", 3).unwrap());
    stack.arm_chaos(Arc::clone(&plan));
    let mut arena = StagingArena::new(stack.arena_capacity());
    for i in 0..8u64 {
        let r = req(i, i, 6);
        let resp = stack.serve(&r, &mut arena).expect("outage must not fail requests");
        assert_eq!(resp.scores.len(), 6 * TASKS, "degraded response keeps full shape");
        assert_eq!(
            resp.quality,
            ServeQuality::StaleFeatures,
            "cold fetch through a dead store must be stamped stale/default"
        );
    }
    assert!(plan.injected().store_errors >= 1, "the plan actually fired");
    let q = stack.metrics.quality_counts();
    assert_eq!(q[ServeQuality::StaleFeatures.index()], 8, "quality histogram: {q:?}");
    assert_eq!(q[ServeQuality::Full.index()], 0);
}

// ---------------------------------------------------------------------
// Ladder rung 2: over-budget request → truncated candidate prefix.
// ---------------------------------------------------------------------

#[test]
fn tight_deadline_truncates_candidates_not_reject() {
    // 1 ms per compute launch; the pace estimator learns ~250 µs/pair
    // from m=4 warmups, so a 13-candidate request under a 2.5 ms budget
    // cannot fit and must serve a truncated prefix.
    let stack = sim_stack(
        |c| {
            c.server.pipeline = true;
            c.server.feature_workers = 1;
            c.server.pipeline_workers = 1;
            c.server.truncate_over_budget = true;
        },
        Duration::from_millis(1),
    );
    let handle = stack.spawn_pipeline();
    for i in 0..5u64 {
        let r = req(i, i, 4);
        handle
            .submit_with_deadline(r, Duration::from_secs(1))
            .unwrap()
            .recv()
            .unwrap()
            .expect("warmup");
    }
    let r = req(100, 100, 13);
    let resp = handle
        .submit_with_deadline(r, Duration::from_micros(2_500))
        .unwrap()
        .recv()
        .unwrap()
        .expect("over-budget request must degrade, not fail");
    assert_eq!(resp.quality, ServeQuality::TruncatedCandidates);
    assert!(
        resp.scores.len() < 13 * TASKS && !resp.scores.is_empty(),
        "a truncated prefix was scored, got {} scores",
        resp.scores.len()
    );
    assert_eq!(resp.scores.len() % TASKS, 0);
    let q = stack.metrics.quality_counts();
    assert!(q[ServeQuality::TruncatedCandidates.index()] >= 1, "histogram: {q:?}");
    handle.shutdown();
}

// ---------------------------------------------------------------------
// Ladder rung 3 (cluster): brownout → hedged re-dispatch wins.
// ---------------------------------------------------------------------

#[test]
fn cluster_brownout_is_routed_around_by_hedging() {
    let sim =
        SimConfig { base_us: 400, per_pair_ns: 0, miss_penalty_us: 0, ..SimConfig::default() };
    let (sims, router) = sim_cluster(3, sim, |c| {
        c.hedge = true;
        c.max_retries = 2;
        c.retry_backoff_us = 50;
    });
    // warmup before arming: the hedge trigger compares against each
    // replica's learned latency estimate
    for i in 0..60u64 {
        router.submit(&req(i, i, 2)).unwrap();
    }
    let plan = Arc::new(FaultPlan::parse("brownout:replica=2,x=12", 7).unwrap());
    for (i, s) in sims.iter().enumerate() {
        s.arm_chaos(i, Arc::clone(&plan));
    }
    for i in 0..60u64 {
        router.submit(&req(1_000 + i, i, 2)).expect("brownout must not fail requests");
    }
    assert!(plan.injected().brownout_hits >= 1, "replica 2 was actually slowed");
    let snap = router.snapshot();
    assert!(snap.hedges >= 1, "a 12x brownout must trigger at least one hedge");
    assert!(
        snap.hedge_wins >= 1,
        "a healthy alternative answers before a 12x-slowed primary"
    );
}

// ---------------------------------------------------------------------
// Crash window: absorbed by retries, throughput recovers within 10%.
// ---------------------------------------------------------------------

#[test]
fn cluster_crash_window_recovers_throughput_within_10_percent() {
    const PHASE: u64 = 150;
    let sim =
        SimConfig { base_us: 300, per_pair_ns: 0, miss_penalty_us: 0, ..SimConfig::default() };
    let (sims, router) = sim_cluster(3, sim, |c| {
        // keep the health machinery out of the picture: this test pins
        // down the retry ladder and the throughput recovery alone
        c.eject_after = 1_000;
        c.max_retries = 2;
        c.retry_backoff_us = 0;
    });
    let run_phase = |base: u64| -> Duration {
        let t0 = Instant::now();
        for i in 0..PHASE {
            router.submit(&req(base + i, i, 2)).expect("every request must succeed");
        }
        t0.elapsed()
    };

    let pre = run_phase(0);

    // storm: replica 0 hard-fails its next 30 serve attempts; round-robin
    // sends it PHASE/3 = 50 picks, so the window fully burns this phase
    let plan = Arc::new(FaultPlan::parse("crash:replica=0,after=0,down=30", 11).unwrap());
    for (i, s) in sims.iter().enumerate() {
        s.arm_chaos(i, Arc::clone(&plan));
    }
    run_phase(10_000);
    assert_eq!(plan.injected().crash_faults, 30, "the whole window was consumed");
    let snap = router.snapshot();
    assert_eq!(snap.retries, 30, "every crash fault was absorbed by exactly one retry");

    let post = run_phase(20_000);
    let ratio = post.as_secs_f64() / pre.as_secs_f64();
    assert!(
        ratio < 1.10,
        "post-storm throughput must be within 10% of pre-storm: pre {pre:?}, post {post:?}"
    );
}

// ---------------------------------------------------------------------
// The combined storm: store timeouts + brownout + crash + worker panics
// through one seeded plan, across both planes (the pipelined stack and
// the cluster router) at once. No request lost, counters match.
// ---------------------------------------------------------------------

#[test]
fn combined_storm_loses_no_request_and_counters_match_plan() {
    const SPEC: &str = "store_timeout:p=0.2,store_delay:p=0.1,us=150,stall:p=0.05,us=200,\
                        brownout:replica=2,x=8,crash:replica=0,after=20,down=25,\
                        panic:worker=feature,n=3,panic:worker=compute,n=6,\
                        panic:worker=executor,n=5";
    let plan = Arc::new(FaultPlan::parse(SPEC, 42).unwrap());

    // plane 1: the pipelined serving stack (store faults, stage/executor
    // panics, compute stalls)
    let stack = sim_stack(
        |c| {
            c.server.pipeline = true;
            c.server.feature_workers = 2;
            c.server.pipeline_workers = 2;
        },
        Duration::ZERO,
    );
    stack.arm_chaos(Arc::clone(&plan));
    let handle = stack.spawn_pipeline();

    // plane 2: the cluster router (brownout, crash window, hedging,
    // retry ladder) — warmed up before arming so estimates are live
    let sim =
        SimConfig { base_us: 300, per_pair_ns: 0, miss_penalty_us: 0, ..SimConfig::default() };
    let (sims, router) = sim_cluster(3, sim, |c| {
        c.hedge = true;
        c.max_retries = 2;
        c.retry_backoff_us = 50;
        c.eject_after = 4;
        c.eject_cooldown_ms = 50;
    });
    for i in 0..60u64 {
        router.submit(&req(i, i, 2)).unwrap();
    }
    for (i, s) in sims.iter().enumerate() {
        s.arm_chaos(i, Arc::clone(&plan));
    }

    // the storm: concurrent clients on both planes; every submission
    // must come back as a response or a typed error
    const CLUSTER_CLIENTS: u64 = 6;
    const CLUSTER_PER: u64 = 30;
    const STACK_CLIENTS: u64 = 4;
    const STACK_PER: u64 = 20;
    let (cluster_ok, cluster_err, stack_ok, stack_err) = std::thread::scope(|s| {
        let mut cluster_handles = Vec::new();
        for t in 0..CLUSTER_CLIENTS {
            let router = Arc::clone(&router);
            cluster_handles.push(s.spawn(move || {
                let (mut ok, mut err) = (0u64, 0u64);
                for i in 0..CLUSTER_PER {
                    let id = 1_000 + t * CLUSTER_PER + i;
                    match router.submit(&req(id, id, 2)) {
                        Ok(_) => ok += 1,
                        Err(Error::Overloaded(_)) => err += 1,
                        Err(e) => panic!("cluster storm: untyped loss: {e}"),
                    }
                }
                (ok, err)
            }));
        }
        let mut stack_handles = Vec::new();
        for t in 0..STACK_CLIENTS {
            let handle = &handle;
            stack_handles.push(s.spawn(move || {
                let (mut ok, mut err) = (0u64, 0u64);
                for i in 0..STACK_PER {
                    let id = 5_000 + t * STACK_PER + i;
                    match handle.serve(&req(id, id, 6)) {
                        Ok(resp) => {
                            assert!(
                                resp.quality <= ServeQuality::TruncatedCandidates,
                                "a computed response sits on a compute rung"
                            );
                            ok += 1;
                        }
                        Err(Error::WorkerPanic(_)) | Err(Error::Overloaded(_)) => err += 1,
                        Err(e) => panic!("stack storm: untyped loss: {e}"),
                    }
                }
                (ok, err)
            }));
        }
        let (mut cok, mut cerr) = (0u64, 0u64);
        for h in cluster_handles {
            let (o, e) = h.join().expect("cluster client must not die");
            cok += o;
            cerr += e;
        }
        let (mut sok, mut serr) = (0u64, 0u64);
        for h in stack_handles {
            let (o, e) = h.join().expect("stack client must not die");
            sok += o;
            serr += e;
        }
        (cok, cerr, sok, serr)
    });

    // no request lost: every submission on both planes is accounted for
    assert_eq!(cluster_ok + cluster_err, CLUSTER_CLIENTS * CLUSTER_PER);
    assert_eq!(stack_ok + stack_err, STACK_CLIENTS * STACK_PER);
    assert!(cluster_ok > 0 && stack_ok > 0, "the storm must not shed everything");

    // the plan actually stormed: every fault class fired
    let inj = plan.injected();
    assert!(inj.store_timeouts >= 1, "injected: {inj:?}");
    assert!(inj.brownout_hits >= 1, "injected: {inj:?}");
    assert!(inj.crash_faults >= 1, "injected: {inj:?}");
    assert_eq!(inj.worker_panics, 3, "each scheduled panic fired exactly once: {inj:?}");

    // recorder counters match the injected plan
    assert_eq!(
        stack.metrics.worker_restarts(),
        inj.worker_panics,
        "every caught panic recorded exactly one supervised restart"
    );
    let snap = router.snapshot();
    assert!(snap.retries >= 1, "crash faults were retried: {snap:?}");
    assert!(snap.hedges >= 1, "the 8x brownout triggered hedging: {snap:?}");
    let q = stack.metrics.quality_counts();
    assert!(
        q[ServeQuality::StaleFeatures.index()] >= 1,
        "store timeouts degraded at least one response to stale: {q:?}"
    );

    // post-storm liveness: the panic schedule is exhausted and the crash
    // window closed; both planes serve cleanly again
    for i in 0..10u64 {
        let id = 90_000 + i;
        handle.serve(&req(id, id, 6)).expect("stage workers survived their panics");
        router.submit(&req(id, id, 2)).expect("the cluster recovered from the storm");
    }
    handle.shutdown();
}
