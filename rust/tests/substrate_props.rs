//! Property tests over the hand-rolled substrates (propcheck harness):
//! JSON round-trips, histogram quantile bounds, LRU invariants, split
//! planner conservation, wire-protocol round-trips, RNG distribution
//! sanity. These are the coordinator invariants DESIGN.md commits to.

use std::time::{Duration, Instant};

use flame::cache::{Lookup, LruCache};
use flame::dso::plan_split;
use flame::metrics::Histogram;
use flame::prop_ensure;
use flame::server::tcp::{decode_request, encode_request};
use flame::util::json::{parse, Json};
use flame::util::propcheck;
use flame::workload::trace::{request_from_line, request_to_line};
use flame::workload::Request;

#[test]
fn prop_json_number_roundtrip() {
    propcheck::check("json number roundtrip", 500, |g| {
        let x = (g.u64_below(1 << 52) as f64) * if g.bool() { -1.0 } else { 1.0 };
        let frac = if g.bool() { 0.5 } else { 0.0 };
        let v = Json::Num(x + frac);
        let back = parse(&v.to_string()).map_err(|e| e.to_string())?;
        prop_ensure!(back == v, "{back:?} != {v:?}");
        Ok(())
    });
}

#[test]
fn prop_json_string_roundtrip() {
    propcheck::check("json string roundtrip", 500, |g| {
        let len = g.usize_in(0, 40);
        let chars: Vec<char> = (0..len)
            .map(|_| {
                match g.u64_below(6) {
                    0 => '"',
                    1 => '\\',
                    2 => '\n',
                    3 => char::from_u32(0x20 + g.u64_below(60) as u32).unwrap(),
                    4 => 'é',
                    _ => '😀',
                }
            })
            .collect();
        let s: String = chars.into_iter().collect();
        let v = Json::Str(s.clone());
        let back = parse(&v.to_string()).map_err(|e| e.to_string())?;
        prop_ensure!(back.as_str().unwrap() == s, "roundtrip failed for {s:?}");
        Ok(())
    });
}

#[test]
fn prop_json_nested_structures() {
    propcheck::check("json nested roundtrip", 200, |g| {
        fn build(g: &mut propcheck::Gen, depth: usize) -> Json {
            if depth == 0 || g.u64_below(3) == 0 {
                match g.u64_below(4) {
                    0 => Json::Null,
                    1 => Json::Bool(g.bool()),
                    2 => Json::Num(g.u64_below(1000) as f64),
                    _ => Json::Str(format!("s{}", g.u64_below(100))),
                }
            } else if g.bool() {
                Json::Arr((0..g.usize_in(0, 4)).map(|_| build(g, depth - 1)).collect())
            } else {
                Json::Obj(
                    (0..g.usize_in(0, 4))
                        .map(|i| (format!("k{i}"), build(g, depth - 1)))
                        .collect(),
                )
            }
        }
        let v = build(g, 4);
        let back = parse(&v.to_string()).map_err(|e| e.to_string())?;
        prop_ensure!(back == v, "nested roundtrip failed");
        Ok(())
    });
}

#[test]
fn prop_histogram_quantiles_bounded_by_minmax() {
    propcheck::check("histogram quantile bounds", 200, |g| {
        let h = Histogram::new();
        let n = g.usize_in(1, 200);
        let mut lo = u64::MAX;
        let mut hi = 0u64;
        for _ in 0..n {
            let v = g.u64_below(10_000_000);
            lo = lo.min(v);
            hi = hi.max(v);
            h.record(v);
        }
        for q in [0.0, 0.25, 0.5, 0.9, 0.99, 1.0] {
            let x = h.quantile(q);
            prop_ensure!(x <= hi, "q{q}={x} > max {hi}");
        }
        prop_ensure!(h.count() == n as u64, "count");
        // quantile monotone in q
        prop_ensure!(
            h.quantile(0.25) <= h.quantile(0.75),
            "quantiles not monotone"
        );
        Ok(())
    });
}

#[test]
fn prop_lru_never_exceeds_capacity_and_keeps_mru() {
    propcheck::check("lru invariants", 300, |g| {
        let cap = g.usize_in(1, 16);
        let mut c: LruCache<u64> = LruCache::new(cap, Duration::from_secs(3600));
        let now = Instant::now();
        let ops = g.usize_in(1, 100);
        let mut last_inserted = None;
        for _ in 0..ops {
            let k = g.u64_below(32);
            if g.bool() {
                c.insert(k, k, now);
                last_inserted = Some(k);
            } else {
                let _ = c.get(k, now);
            }
            prop_ensure!(c.len() <= cap, "len {} > cap {cap}", c.len());
        }
        // the most recently inserted key must still be present
        if let Some(k) = last_inserted {
            prop_ensure!(
                !matches!(c.get(k, now), Lookup::Miss),
                "MRU key {k} evicted"
            );
        }
        // mru list length == len
        prop_ensure!(c.keys_mru().len() == c.len(), "mru list length mismatch");
        Ok(())
    });
}

#[test]
fn prop_planner_total_conservation_random_profiles() {
    propcheck::check("planner conservation", 1000, |g| {
        let mut profiles = g.vec_usize(1, 6, 1, 512);
        profiles.sort_unstable();
        profiles.dedup();
        let m = g.usize_in(0, 4096);
        let plan = plan_split(m, &profiles);
        let total: usize = plan.chunks.iter().sum();
        prop_ensure!(total == m + plan.padding, "conservation");
        prop_ensure!(total >= m, "coverage");
        for c in &plan.chunks {
            prop_ensure!(profiles.contains(c), "alien chunk {c}");
        }
        Ok(())
    });
}

#[test]
fn prop_wire_request_roundtrip() {
    propcheck::check("wire request roundtrip", 300, |g| {
        let req = Request {
            request_id: g.u64_below(u64::MAX / 2),
            user_id: g.u64_below(1 << 40),
            history: (0..g.usize_in(0, 64)).map(|_| g.u64_below(1 << 48)).collect(),
            candidates: (0..g.usize_in(0, 32)).map(|_| g.u64_below(1 << 48)).collect(),
            ..Default::default()
        };
        let back = decode_request(&encode_request(&req)).map_err(|e| e.to_string())?;
        prop_ensure!(back == req, "wire roundtrip");
        Ok(())
    });
}

#[test]
fn prop_trace_line_roundtrip() {
    propcheck::check("trace jsonl roundtrip", 300, |g| {
        let req = Request {
            request_id: g.u64_below(1 << 50),
            user_id: g.u64_below(1 << 30),
            history: (0..g.usize_in(0, 16)).map(|_| g.u64_below(1 << 50)).collect(),
            candidates: (0..g.usize_in(1, 8)).map(|_| g.u64_below(1 << 50)).collect(),
            // the trace layer carries tenancy; roundtrip all 8 slots
            tenant: flame::workload::TenantId(g.u64_below(8) as u8),
        };
        let back = request_from_line(&request_to_line(&req)).map_err(|e| e.to_string())?;
        prop_ensure!(back == req, "trace roundtrip");
        Ok(())
    });
}

#[test]
fn prop_rng_below_always_in_range() {
    propcheck::check("rng below range", 500, |g| {
        let n = 1 + g.u64_below(1 << 40);
        let x = g.rng().below(n);
        prop_ensure!(x < n, "{x} >= {n}");
        Ok(())
    });
}

#[test]
fn prop_decode_rejects_truncation() {
    // any strict prefix of a valid frame must fail to decode, not panic
    propcheck::check("wire truncation safety", 200, |g| {
        let req = Request {
            request_id: 1,
            user_id: 2,
            history: (0..g.usize_in(1, 8)).map(|_| g.u64_below(100)).collect(),
            candidates: (0..g.usize_in(1, 8)).map(|_| g.u64_below(100)).collect(),
            ..Default::default()
        };
        let buf = encode_request(&req);
        let cut = g.usize_in(0, buf.len());
        if cut < buf.len() {
            prop_ensure!(
                decode_request(&buf[..cut]).is_err(),
                "truncated frame decoded at {cut}/{}",
                buf.len()
            );
        }
        Ok(())
    });
}
