//! PDA integration: cached feature pipeline against the simulated remote
//! store under Zipf traffic — the mechanics behind Table 3, asserted
//! qualitatively (cache cuts network bytes and feature latency; staging
//! and owned assembly agree bit-for-bit). No artifacts required.

use std::sync::Arc;
use std::time::{Duration, Instant};

use flame::cache::Lookup;
use flame::config::{CacheMode, PdaConfig, WorkloadConfig};
use flame::embedding::EmbeddingTable;
use flame::featurestore::{FeatureSchema, RemoteStore};
use flame::netsim::{Link, LinkConfig};
use flame::pda::{InputAssembler, QueryEngine, StagingArena};
use flame::workload::Generator;

fn link() -> Arc<Link> {
    Arc::new(Link::new(LinkConfig {
        rtt: Duration::from_micros(400),
        bandwidth_bps: 200e6,
        jitter: 0.0,
        fail_rate: 0.0,
    }))
}

fn pda_cfg(mode: CacheMode) -> PdaConfig {
    PdaConfig {
        cache_mode: mode,
        cache_capacity: 50_000,
        cache_shards: 16,
        cache_ttl_ms: 60_000,
        refresh_workers: 2,
        ..PdaConfig::default()
    }
}

fn workload() -> Generator {
    Generator::new(
        &WorkloadConfig {
            catalog_size: 20_000,
            zipf_theta: 1.05,
            n_users: 500,
            candidate_mix: vec![(32, 1.0)],
            arrival_rate: None,
            seed: 99,
        },
        32,
    )
}

#[test]
fn cache_cuts_network_traffic_under_zipf() {
    let run = |mode: CacheMode| -> (u64, Duration) {
        let l = link();
        let store = Arc::new(RemoteStore::new(FeatureSchema::default(), Arc::clone(&l), 5));
        let q = QueryEngine::new(&pda_cfg(mode), store);
        let mut gen = workload();
        let t = Instant::now();
        for _ in 0..150 {
            let r = gen.next_request();
            q.fetch(&r.candidates);
        }
        q.drain_refreshes();
        (l.bytes_total(), t.elapsed())
    };
    let (bytes_off, time_off) = run(CacheMode::Off);
    let (bytes_sync, time_sync) = run(CacheMode::Sync);
    // Zipf-hot candidates: the sync cache must save a large share of bytes
    assert!(
        (bytes_sync as f64) < 0.7 * bytes_off as f64,
        "sync {bytes_sync} vs off {bytes_off}"
    );
    // and the wall time must drop too (fewer blocking RTTs)
    assert!(time_sync < time_off, "sync {time_sync:?} vs off {time_off:?}");
}

#[test]
fn async_mode_faster_than_sync_after_warmup() {
    let l = link();
    let store = Arc::new(RemoteStore::new(FeatureSchema::default(), Arc::clone(&l), 5));
    let q_async = QueryEngine::new(&pda_cfg(CacheMode::Async), Arc::clone(&store));
    let mut gen = workload();

    // warmup: let refreshes land
    for _ in 0..100 {
        let r = gen.next_request();
        q_async.fetch(&r.candidates);
    }
    q_async.drain_refreshes();

    // measured phase: async never blocks on the link
    let t = Instant::now();
    for _ in 0..100 {
        let r = gen.next_request();
        q_async.fetch(&r.candidates);
    }
    let async_time = t.elapsed();
    // 100 requests with zero blocking RTTs must be far under 100 * rtt
    assert!(
        async_time < Duration::from_millis(30),
        "async warm path took {async_time:?}"
    );
}

#[test]
fn sync_cache_values_equal_remote_values() {
    // caching must never change the feature bytes (accuracy preservation)
    let l = link();
    let store = Arc::new(RemoteStore::new(FeatureSchema::default(), Arc::clone(&l), 5));
    let q = QueryEngine::new(&pda_cfg(CacheMode::Sync), Arc::clone(&store));
    let ids = [3u64, 14, 15, 92, 65];
    let first = q.fetch(&ids);
    let second = q.fetch(&ids);
    for ((a, _), (b, _)) in first.iter().zip(second.iter()) {
        assert_eq!(a, b);
    }
    // direct store values agree too
    let direct = store.fetch_batch(&ids);
    for ((cached, _), fresh) in second.iter().zip(direct.iter()) {
        assert_eq!(cached, fresh);
    }
}

#[test]
fn assembler_staging_matches_owned_under_full_pipeline() {
    let l = link();
    let store = Arc::new(RemoteStore::new(FeatureSchema::default(), Arc::clone(&l), 5));
    let q = Arc::new(QueryEngine::new(&pda_cfg(CacheMode::Sync), store));
    let table = Arc::new(EmbeddingTable::new(16, 2, 4096));

    let staged = InputAssembler::new(Arc::clone(&table), Arc::clone(&q), true);
    let owned = InputAssembler::new(table, q, false);

    let mut gen = workload();
    let mut arena = StagingArena::new(1 << 16);
    let mut dummy = StagingArena::new(1);
    for _ in 0..10 {
        let r = gen.next_request();
        let a = staged.assemble(&r.history, &r.candidates, &mut arena);
        let b = owned.assemble(&r.history, &r.candidates, &mut dummy);
        let (ah, ac) = a.views(&arena);
        let (bh, bc) = b.views(&dummy);
        assert_eq!(ah, bh);
        assert_eq!(ac, bc);
    }
}

#[test]
fn hot_items_stay_resident_under_pressure() {
    // capacity-constrained cache: the Zipf head must survive eviction
    let l = link();
    let store = Arc::new(RemoteStore::new(FeatureSchema::default(), Arc::clone(&l), 5));
    let mut cfg = pda_cfg(CacheMode::Sync);
    cfg.cache_capacity = 512; // tiny vs 20k catalog
    let q = QueryEngine::new(&cfg, store);
    let mut gen = workload();
    for _ in 0..300 {
        let r = gen.next_request();
        q.fetch(&r.candidates);
    }
    // the hottest item (rank 0 under the catalog permutation) should be
    // cached; probe it directly through the cache.
    let catalog = gen.catalog().clone();
    let hot = catalog.id_of_rank(0);
    match q.cache().get(hot) {
        Lookup::Fresh(_) | Lookup::Stale(_) => {}
        Lookup::Miss => panic!("hottest item evicted from a 512-entry cache"),
    }
    let rate = q.cache().stats.hit_rate();
    assert!(rate > 0.3, "hit rate {rate} too low under Zipf 1.05");
}
