//! End-to-end numeric validation: load the tiny scenario's HLO artifacts
//! through the PJRT runtime and check outputs against the python-executed
//! test vectors (aot.py dumps inputs + expected scores).
//!
//! Requires `make artifacts` (tiny scenario) to have run.

use std::sync::Arc;

use flame::manifest::testvec::{max_abs_diff, TestVector};
use flame::manifest::Manifest;
use flame::runtime::{EngineKey, Runtime};

const TOL: f32 = 2e-4;

fn manifest() -> Option<Manifest> {
    match Manifest::load("artifacts") {
        Ok(m) if m.scenarios.contains_key("tiny") => Some(m),
        _ => {
            eprintln!("skipping: artifacts/tiny not built (run `make artifacts`)");
            None
        }
    }
}

fn runtime() -> Option<Runtime> {
    match Runtime::new() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping: PJRT runtime unavailable ({e})");
            None
        }
    }
}

#[test]
fn tiny_engines_match_python_testvectors() {
    let Some(m) = manifest() else { return };
    let Some(rt) = runtime() else { return };
    let weights = rt.upload_weights(&m, "tiny").expect("weights");

    let tvs: Vec<_> = m.testvectors.iter().filter(|t| t.scenario == "tiny").collect();
    assert!(!tvs.is_empty(), "no tiny test vectors in manifest");

    // group by engine to compile each once
    let mut keys: Vec<EngineKey> = tvs
        .iter()
        .map(|t| EngineKey::new("tiny", &t.variant, t.m))
        .collect();
    keys.sort_by_key(|k| k.label());
    keys.dedup();

    for key in keys {
        let engine = rt
            .load_engine_with_weights(&m, &key, Arc::clone(&weights))
            .unwrap_or_else(|e| panic!("load {}: {e}", key.label()));
        for t in tvs.iter().filter(|t| t.variant == key.variant && t.m == key.m) {
            let tv = TestVector::load(&m.path_of(&t.path)).expect("testvec");
            let hist = tv.get("hist").unwrap();
            let cands = tv.get("cands").unwrap();
            let expect = tv.get("scores").unwrap();
            let got = engine.run(&hist.data, &cands.data).expect("run");
            assert_eq!(got.len(), expect.data.len(), "{}", key.label());
            let diff = max_abs_diff(&got, &expect.data);
            assert!(
                diff < TOL,
                "{} vs python: max |diff| = {diff} (tol {TOL})",
                key.label()
            );
        }
    }
}

#[test]
fn variants_agree_with_each_other() {
    // naive / api / fused are the same model; rust-side outputs on the
    // same inputs must agree across engines.
    let Some(m) = manifest() else { return };
    let Some(rt) = runtime() else { return };
    let weights = rt.upload_weights(&m, "tiny").expect("weights");
    let cfg = &m.scenario("tiny").unwrap().config;
    let mm = cfg.native_m;

    let mut outputs = Vec::new();
    for variant in ["naive", "api", "fused"] {
        if m.find("tiny", variant, mm).is_err() {
            continue;
        }
        let key = EngineKey::new("tiny", variant, mm);
        let engine = rt
            .load_engine_with_weights(&m, &key, Arc::clone(&weights))
            .expect("load");
        // deterministic input
        let hist: Vec<f32> = (0..cfg.seq_len * cfg.d_model)
            .map(|i| ((i * 37 % 101) as f32 / 101.0) - 0.5)
            .collect();
        let cands: Vec<f32> = (0..mm * cfg.d_model)
            .map(|i| ((i * 53 % 97) as f32 / 97.0) - 0.5)
            .collect();
        outputs.push((variant, engine.run(&hist, &cands).unwrap()));
    }
    assert!(outputs.len() >= 2, "need at least two variants built");
    for w in outputs.windows(2) {
        let d = max_abs_diff(&w[0].1, &w[1].1);
        assert!(d < TOL, "{} vs {}: {d}", w[0].0, w[1].0);
    }
}

#[test]
fn scores_are_probabilities() {
    let Some(m) = manifest() else { return };
    let Some(rt) = runtime() else { return };
    let cfg = m.scenario("tiny").unwrap().config.clone();
    let key = EngineKey::new("tiny", "fused", cfg.native_m);
    if m.find("tiny", "fused", cfg.native_m).is_err() {
        return;
    }
    let engine = rt.load_engine(&m, &key).expect("load");
    let hist = vec![0.25f32; engine.hist_len()];
    let cands = vec![-0.25f32; engine.cands_len()];
    let scores = engine.run(&hist, &cands).unwrap();
    assert_eq!(scores.len(), cfg.native_m * cfg.n_tasks);
    assert!(scores.iter().all(|&s| (0.0..=1.0).contains(&s)), "sigmoid outputs");
}

#[test]
fn engine_rejects_wrong_input_lengths() {
    let Some(m) = manifest() else { return };
    let Some(rt) = runtime() else { return };
    let cfg = m.scenario("tiny").unwrap().config.clone();
    let key = EngineKey::new("tiny", "api", cfg.native_m);
    if m.find("tiny", "api", cfg.native_m).is_err() {
        return;
    }
    let engine = rt.load_engine(&m, &key).expect("load");
    let bad_hist = vec![0.0f32; 3];
    let cands = vec![0.0f32; engine.cands_len()];
    assert!(engine.run(&bad_hist, &cands).is_err());
}

#[test]
fn flops_manifest_agrees_with_rust_formula() {
    let Some(m) = manifest() else { return };
    // Manifest::validate already checks this, but assert explicitly so a
    // formula drift is reported with context.
    for e in &m.models {
        let cfg = &m.scenario(&e.scenario).unwrap().config;
        assert_eq!(
            e.flops,
            flame::config::flops::model_flops(cfg, e.m),
            "{}/{}/m{}",
            e.scenario,
            e.variant,
            e.m
        );
    }
}
