//! DSO integration: explicit-shape split routing vs implicit pad-to-max,
//! result correctness under splitting, concurrency, admission control,
//! and the cross-request batch coalescer.
//!
//! The first section runs over real engines (tiny scenario) and gates on
//! artifacts + a PJRT runtime. The second section drives the
//! orchestrator over the artifact-free deterministic `SimEngine`
//! backend, so the coalescer's score-identity, latency-bound, admission,
//! and compute-timing contracts are exercised on every bare checkout.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

use flame::config::{DsoConfig, DsoMode};
use flame::dso::{ComputeBackend, Orchestrator, SimEngine};
use flame::manifest::testvec::max_abs_diff;
use flame::manifest::Manifest;
use flame::runtime::{EngineKey, Runtime};
use flame::util::propcheck;

fn setup(mode: DsoMode) -> Option<(Orchestrator, flame::config::ModelConfig)> {
    let m = Manifest::load("artifacts").ok()?;
    if !m.scenarios.contains_key("tiny") {
        eprintln!("skipping: artifacts/tiny not built");
        return None;
    }
    let rt = match Runtime::new() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping: PJRT runtime unavailable ({e})");
            return None;
        }
    };
    let engines = rt.load_profile_set(&m, "tiny", "fused").ok()?;
    let cfg = m.scenario("tiny").unwrap().config.clone();
    let orch = Orchestrator::new(
        engines,
        &DsoConfig {
            mode,
            executors_per_profile: 2,
            queue_capacity: 256,
            ..DsoConfig::default()
        },
    )
    .ok()?;
    Some((orch, cfg))
}

fn inputs(cfg: &flame::config::ModelConfig, m: usize, salt: u64) -> (Arc<Vec<f32>>, Vec<f32>) {
    let hist: Vec<f32> = (0..cfg.seq_len * cfg.d_model)
        .map(|i| (((i as u64 + salt) * 31 % 113) as f32 / 113.0) - 0.5)
        .collect();
    let cands: Vec<f32> = (0..m * cfg.d_model)
        .map(|i| (((i as u64 + salt) * 17 % 127) as f32 / 127.0) - 0.5)
        .collect();
    (Arc::new(hist), cands)
}

#[test]
fn split_results_match_single_engine() {
    // A request of m = p1 + p2 split across profiles must score each
    // candidate exactly as a direct run of the right profile would.
    let Some((orch, cfg)) = setup(DsoMode::Explicit) else { return };
    let profiles = orch.profiles().to_vec(); // tiny: [4, 8]
    assert_eq!(profiles, vec![4, 8]);
    let m = 12; // 8 + 4 exact split, no padding
    let (hist, cands) = inputs(&cfg, m, 3);
    let out = orch.submit(Arc::clone(&hist), &cands, m).expect("submit");
    assert_eq!(out.chunks, vec![8, 4]);
    assert_eq!(out.padding, 0);
    assert_eq!(out.scores.len(), m * cfg.n_tasks);

    // direct comparison: run the 8-profile engine on candidates 0..8
    let manifest = Manifest::load("artifacts").unwrap();
    let rt = Runtime::new().unwrap();
    let e8 = rt.load_engine(&manifest, &EngineKey::new("tiny", "fused", 8)).unwrap();
    let direct = e8.run(&hist, &cands[..8 * cfg.d_model]).unwrap();
    let diff = max_abs_diff(&out.scores[..8 * cfg.n_tasks], &direct);
    assert!(diff < 1e-5, "split chunk disagrees with direct run: {diff}");
}

#[test]
fn padding_stripped_and_scores_stable() {
    // m = 5 pads to 8; the 5 real scores must equal the unpadded prefix
    // of a direct 8-run with repeated-last-row padding.
    let Some((orch, cfg)) = setup(DsoMode::Explicit) else { return };
    let m = 5;
    let (hist, cands) = inputs(&cfg, m, 9);
    let out = orch.submit(Arc::clone(&hist), &cands, m).expect("submit");
    assert_eq!(out.scores.len(), m * cfg.n_tasks);
    assert_eq!(out.padding, 3);
    assert!(out.scores.iter().all(|s| (0.0..=1.0).contains(s)));
}

#[test]
fn implicit_mode_always_pads_to_max() {
    let Some((orch, cfg)) = setup(DsoMode::ImplicitPad) else { return };
    let (hist, cands) = inputs(&cfg, 4, 1);
    let out = orch.submit(hist, &cands, 4).expect("submit");
    assert_eq!(out.chunks, vec![8]);
    assert_eq!(out.padding, 4);
    // waste accounting reflects it
    assert!(orch.waste_fraction() > 0.4);
}

#[test]
fn explicit_wastes_less_than_implicit_on_mixed_m() {
    let Some((explicit, cfg)) = setup(DsoMode::Explicit) else { return };
    let Some((implicit, _)) = setup(DsoMode::ImplicitPad) else { return };
    for salt in 0..8u64 {
        let m = [4usize, 5, 8, 12][salt as usize % 4];
        let (h, c) = inputs(&cfg, m, salt);
        explicit.submit(Arc::clone(&h), &c, m).unwrap();
        implicit.submit(h, &c, m).unwrap();
    }
    assert!(
        explicit.waste_fraction() < implicit.waste_fraction(),
        "explicit {} vs implicit {}",
        explicit.waste_fraction(),
        implicit.waste_fraction()
    );
}

#[test]
fn concurrent_submissions_consistent() {
    let Some((orch, cfg)) = setup(DsoMode::Explicit) else { return };
    let orch = Arc::new(orch);
    // same request from 4 threads: identical scores
    let (hist, cands) = inputs(&cfg, 8, 5);
    let expected = orch.submit(Arc::clone(&hist), &cands, 8).unwrap().scores;
    let hs: Vec<_> = (0..4)
        .map(|_| {
            let orch = Arc::clone(&orch);
            let hist = Arc::clone(&hist);
            let cands = cands.clone();
            std::thread::spawn(move || orch.submit(hist, &cands, 8).unwrap().scores)
        })
        .collect();
    for h in hs {
        let got = h.join().unwrap();
        assert!(max_abs_diff(&got, &expected) < 1e-6);
    }
}

#[test]
fn zero_candidates_is_empty_ok() {
    let Some((orch, cfg)) = setup(DsoMode::Explicit) else { return };
    let (hist, _) = inputs(&cfg, 4, 0);
    let out = orch.submit(hist, &[], 0).unwrap();
    assert!(out.scores.is_empty());
    assert!(out.chunks.is_empty());
}

#[test]
fn mismatched_cands_len_rejected() {
    let Some((orch, cfg)) = setup(DsoMode::Explicit) else { return };
    let (hist, cands) = inputs(&cfg, 4, 0);
    assert!(orch.submit(hist, &cands[..cands.len() - 1], 4).is_err());
}

// ---------------------------------------------------------------------
// Artifact-free section: the orchestrator over the deterministic
// SimEngine backend (native per-segment history binding). Runs on every
// bare checkout — no artifacts, no PJRT.
// ---------------------------------------------------------------------

const SEQ: usize = 16;
const D: usize = 8;
const TASKS: usize = 3;

fn sim_orch(profiles: &[usize], cfg: &DsoConfig, delay: Duration) -> Orchestrator {
    let backends: Vec<Arc<dyn ComputeBackend>> = profiles
        .iter()
        .map(|&m| {
            Arc::new(SimEngine::new(m, SEQ, D, TASKS).with_delay(delay))
                as Arc<dyn ComputeBackend>
        })
        .collect();
    Orchestrator::from_backends(backends, cfg, None).expect("sim orchestrator")
}

fn sim_inputs(m: usize, salt: u64) -> (Vec<f32>, Vec<f32>) {
    let hist: Vec<f32> = (0..SEQ * D)
        .map(|i| (((i as u64 + salt) * 31 % 113) as f32 / 113.0) - 0.5)
        .collect();
    let cands: Vec<f32> = (0..m * D)
        .map(|i| (((i as u64 + salt) * 17 % 127) as f32 / 127.0) - 0.5)
        .collect();
    (hist, cands)
}

fn coalesce_cfg(wait_us: u64) -> DsoConfig {
    DsoConfig {
        mode: DsoMode::Explicit,
        executors_per_profile: 2,
        queue_capacity: 1024,
        coalesce: true,
        coalesce_wait_us: wait_us,
    }
}

#[test]
fn sim_split_and_pad_work_without_artifacts() {
    let orch = sim_orch(&[4, 8], &DsoConfig::default(), Duration::ZERO);
    for (m, salt) in [(1usize, 1u64), (5, 2), (8, 3), (12, 4), (13, 5)] {
        let (hist, cands) = sim_inputs(m, salt);
        let out = orch.submit_slice(&hist, &cands, m).expect("submit");
        assert_eq!(out.scores.len(), m * TASKS);
        assert!(out.scores.iter().all(|s| (0.0..=1.0).contains(s)));
    }
    let (hist, _) = sim_inputs(4, 0);
    assert!(orch.submit_slice(&hist, &[], 0).unwrap().scores.is_empty());
}

/// Acceptance criterion: for any interleaving of concurrent requests,
/// coalesced execution returns bit-identical scores (per request, in
/// request candidate order) to the non-coalesced path. The SimEngine
/// scores each row as a pure function of (history, row), so any
/// discrepancy can only come from the coalescer mis-packing or
/// mis-demuxing rows.
#[test]
fn prop_coalesced_scores_bit_identical_under_interleaving() {
    let baseline = Arc::new(sim_orch(&[4, 8], &DsoConfig::default(), Duration::ZERO));
    let coalesced = Arc::new(sim_orch(&[4, 8], &coalesce_cfg(2_000), Duration::ZERO));
    propcheck::check("coalesced == split scores", 30, |g| {
        let n_req = g.usize_in(2, 7);
        let reqs: Vec<(usize, u64)> = (0..n_req)
            .map(|_| (g.usize_in(1, 13), g.u64_below(1 << 30)))
            .collect();
        // expected: each request alone through the non-coalesced path
        let expected: Vec<Vec<f32>> = reqs
            .iter()
            .map(|&(m, salt)| {
                let (hist, cands) = sim_inputs(m, salt);
                baseline.submit_slice(&hist, &cands, m).unwrap().scores
            })
            .collect();
        // actual: all requests concurrently through the coalescer — the
        // barrier maximizes interleaving so remainders really pack
        let barrier = Arc::new(Barrier::new(n_req));
        let got: Vec<Vec<f32>> = std::thread::scope(|s| {
            let handles: Vec<_> = reqs
                .iter()
                .map(|&(m, salt)| {
                    let orch = Arc::clone(&coalesced);
                    let barrier = Arc::clone(&barrier);
                    s.spawn(move || {
                        let (hist, cands) = sim_inputs(m, salt);
                        barrier.wait();
                        orch.submit_slice(&hist, &cands, m).unwrap().scores
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for (i, (e, a)) in expected.iter().zip(&got).enumerate() {
            if e != a {
                return Err(format!(
                    "request {i} (m={}, salt={}) scores diverged under coalescing",
                    reqs[i].0, reqs[i].1
                ));
            }
        }
        Ok(())
    });
}

/// Satellite regression: `compute_us` is the engine launch alone — an
/// injected executor-queue stall must show up in `queue_us`, not in
/// `compute_us` (it used to be measured as `submit_t.elapsed()`, which
/// counted the whole queue wait).
#[test]
fn compute_us_excludes_injected_queue_stall() {
    let delay = Duration::from_millis(80);
    let orch = Arc::new(sim_orch(
        &[8],
        &DsoConfig { executors_per_profile: 1, ..DsoConfig::default() },
        delay,
    ));
    let (hist, cands) = sim_inputs(8, 1);
    // occupy the single executor ...
    let first = {
        let orch = Arc::clone(&orch);
        let (hist, cands) = (hist.clone(), cands.clone());
        std::thread::spawn(move || orch.submit_slice(&hist, &cands, 8).unwrap())
    };
    std::thread::sleep(Duration::from_millis(10));
    // ... so this request stalls in the queue for ~the first's compute.
    // Buggy accounting (submit→reply wall time) would report roughly
    // 2x delay here; the fix reports ~1x.
    let stalled = orch.submit_slice(&hist, &cands, 8).unwrap();
    first.join().unwrap();
    let delay_us = delay.as_micros() as u64;
    assert!(
        stalled.compute_us < delay_us + delay_us / 2,
        "compute_us {}µs still includes the queue stall (engine launch is ~{delay_us}µs)",
        stalled.compute_us
    );
    assert!(
        stalled.compute_us >= delay_us / 2,
        "compute_us {}µs lost the launch itself",
        stalled.compute_us
    );
    assert!(
        stalled.queue_us >= delay_us / 3,
        "queue_us {}µs missed the injected stall",
        stalled.queue_us
    );
}

/// Satellite regression: admission is a single atomic reservation — the
/// old load-then-compare check let concurrent submits overshoot
/// `queue_capacity`.
#[test]
fn concurrent_submits_never_exceed_queue_capacity() {
    const CAPACITY: usize = 3;
    const THREADS: usize = 12;
    let orch = Arc::new(sim_orch(
        &[8],
        &DsoConfig {
            executors_per_profile: 4,
            queue_capacity: CAPACITY,
            ..DsoConfig::default()
        },
        Duration::from_millis(150),
    ));
    let barrier = Arc::new(Barrier::new(THREADS));
    let max_seen = Arc::new(AtomicUsize::new(0));
    let stop = Arc::new(AtomicUsize::new(0));
    let sampler = {
        let orch = Arc::clone(&orch);
        let max_seen = Arc::clone(&max_seen);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            while stop.load(Ordering::Acquire) == 0 {
                max_seen.fetch_max(orch.in_flight(), Ordering::AcqRel);
                std::thread::sleep(Duration::from_micros(200));
            }
        })
    };
    let (ok, rejected): (usize, usize) = std::thread::scope(|s| {
        let handles: Vec<_> = (0..THREADS)
            .map(|i| {
                let orch = Arc::clone(&orch);
                let barrier = Arc::clone(&barrier);
                s.spawn(move || {
                    let (hist, cands) = sim_inputs(8, i as u64);
                    barrier.wait();
                    orch.submit_slice(&hist, &cands, 8).is_ok()
                })
            })
            .collect();
        let results: Vec<bool> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        (
            results.iter().filter(|&&r| r).count(),
            results.iter().filter(|&&r| !r).count(),
        )
    });
    stop.store(1, Ordering::Release);
    sampler.join().unwrap();
    assert_eq!(ok + rejected, THREADS);
    assert!(ok >= 1, "someone must get through");
    assert!(rejected >= 1, "overload must shed");
    assert!(
        max_seen.load(Ordering::Acquire) <= CAPACITY,
        "in-flight reservations exceeded capacity: {} > {CAPACITY}",
        max_seen.load(Ordering::Acquire)
    );
}

#[test]
fn coalescer_packs_concurrent_remainders_into_shared_launches() {
    const N: usize = 8;
    let orch = Arc::new(sim_orch(&[8], &coalesce_cfg(50_000), Duration::ZERO));
    let baseline = sim_orch(&[8], &DsoConfig::default(), Duration::ZERO);
    let barrier = Arc::new(Barrier::new(N));
    let got: Vec<Vec<f32>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..N)
            .map(|i| {
                let orch = Arc::clone(&orch);
                let barrier = Arc::clone(&barrier);
                s.spawn(move || {
                    let (hist, cands) = sim_inputs(1, i as u64);
                    barrier.wait();
                    orch.submit_slice(&hist, &cands, 1).unwrap().scores
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    // correctness: every request got its own scores
    for (i, scores) in got.iter().enumerate() {
        let (hist, cands) = sim_inputs(1, i as u64);
        let expected = baseline.submit_slice(&hist, &cands, 1).unwrap().scores;
        assert_eq!(scores, &expected, "request {i}");
    }
    // efficiency: solo execution would burn N launches x 8 rows = 64
    // rows; packing must do strictly better
    let executed = orch.executed_rows_total.load(Ordering::Relaxed);
    assert!(executed < (N * 8) as u64, "no packing happened: {executed} rows executed");
    let stats = orch.coalesce_stats();
    assert!(stats.batches >= 1);
    assert!(
        stats.multi_request_batches >= 1,
        "at least one launch must carry rows from several requests: {stats:?}"
    );
    assert!(stats.coalesced_rows >= 2, "{stats:?}");
    assert!(stats.occupancy_mean_pct > 0.0);
}

#[test]
fn coalesce_wait_bounds_added_latency_and_accounts_padding() {
    let wait_us = 30_000u64;
    let orch = sim_orch(&[8], &coalesce_cfg(wait_us), Duration::ZERO);
    let (hist, cands) = sim_inputs(1, 7);
    let t0 = Instant::now();
    let out = orch.submit_slice(&hist, &cands, 1).expect("submit");
    let elapsed = t0.elapsed();
    assert_eq!(out.scores.len(), TASKS);
    // a lone remainder has nobody to pack with: it must wait out the
    // deadline (lower bound proves the flush is deadline-driven) but
    // never hang (upper bound is generous for loaded CI machines)
    assert!(
        elapsed >= Duration::from_micros(wait_us / 2),
        "flushed after {elapsed:?}, before the coalesce window"
    );
    assert!(elapsed < Duration::from_secs(5), "deadline flush never fired: {elapsed:?}");
    // the queue delay (incl. the coalesce wait) is visible as queue_us
    assert!(out.queue_us >= wait_us / 2, "queue_us {} missed the wait", out.queue_us);
    // realized padding is accounted at flush time
    assert_eq!(orch.executed_rows_total.load(Ordering::Relaxed), 8);
    assert_eq!(orch.padded_rows_total.load(Ordering::Relaxed), 7);
    let stats = orch.coalesce_stats();
    assert_eq!(stats.batches, 1);
    assert_eq!(stats.multi_request_batches, 0);
    assert_eq!(stats.occupancy_p50_pct, 12, "1 of 8 rows real = 12%");
}

#[test]
fn coalescing_reduces_waste_on_skewed_mix() {
    // zipf-ish skew: mostly tiny remainders, occasional full profile
    let ms: Vec<usize> = (0..24).map(|i| [1usize, 2, 1, 3, 8, 1][i % 6]).collect();
    let run = |coalesce: bool| -> f64 {
        let cfg = if coalesce { coalesce_cfg(100_000) } else { DsoConfig::default() };
        let orch = Arc::new(sim_orch(&[4, 8], &cfg, Duration::ZERO));
        for wave in ms.chunks(8) {
            let barrier = Arc::new(Barrier::new(wave.len()));
            std::thread::scope(|s| {
                for (i, &m) in wave.iter().enumerate() {
                    let orch = Arc::clone(&orch);
                    let barrier = Arc::clone(&barrier);
                    s.spawn(move || {
                        let (hist, cands) = sim_inputs(m, i as u64);
                        barrier.wait();
                        orch.submit_slice(&hist, &cands, m).unwrap();
                    });
                }
            });
        }
        orch.waste_fraction()
    };
    let without = run(false);
    let with = run(true);
    assert!(
        with < without,
        "coalescing must cut padded-row waste: with {with:.3} vs without {without:.3}"
    );
}

#[test]
fn coalesce_stats_reach_attached_recorder() {
    use flame::metrics::Recorder;
    let recorder = Arc::new(Recorder::new());
    let backends: Vec<Arc<dyn ComputeBackend>> = vec![Arc::new(SimEngine::new(8, SEQ, D, TASKS))];
    let orch =
        Orchestrator::from_backends(backends, &coalesce_cfg(5_000), Some(Arc::clone(&recorder)))
            .unwrap();
    let (hist, cands) = sim_inputs(3, 1);
    orch.submit_slice(&hist, &cands, 3).unwrap();
    assert_eq!(recorder.coalesce_batches(), 1);
    let snap = recorder.snapshot();
    assert_eq!(snap.coalesce_batches, 1);
    assert!(snap.coalesce_occupancy_mean_pct > 0.0);
}
