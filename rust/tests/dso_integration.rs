//! DSO integration over real engines (tiny scenario): explicit-shape
//! split routing vs implicit pad-to-max, result correctness under
//! splitting, concurrency, and admission control.

use std::sync::Arc;

use flame::config::{DsoConfig, DsoMode};
use flame::dso::Orchestrator;
use flame::manifest::testvec::max_abs_diff;
use flame::manifest::Manifest;
use flame::runtime::{EngineKey, Runtime};

fn setup(mode: DsoMode) -> Option<(Orchestrator, flame::config::ModelConfig)> {
    let m = Manifest::load("artifacts").ok()?;
    if !m.scenarios.contains_key("tiny") {
        eprintln!("skipping: artifacts/tiny not built");
        return None;
    }
    let rt = match Runtime::new() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping: PJRT runtime unavailable ({e})");
            return None;
        }
    };
    let engines = rt.load_profile_set(&m, "tiny", "fused").ok()?;
    let cfg = m.scenario("tiny").unwrap().config.clone();
    let orch = Orchestrator::new(
        engines,
        &DsoConfig { mode, executors_per_profile: 2, queue_capacity: 256 },
    )
    .ok()?;
    Some((orch, cfg))
}

fn inputs(cfg: &flame::config::ModelConfig, m: usize, salt: u64) -> (Arc<Vec<f32>>, Vec<f32>) {
    let hist: Vec<f32> = (0..cfg.seq_len * cfg.d_model)
        .map(|i| (((i as u64 + salt) * 31 % 113) as f32 / 113.0) - 0.5)
        .collect();
    let cands: Vec<f32> = (0..m * cfg.d_model)
        .map(|i| (((i as u64 + salt) * 17 % 127) as f32 / 127.0) - 0.5)
        .collect();
    (Arc::new(hist), cands)
}

#[test]
fn split_results_match_single_engine() {
    // A request of m = p1 + p2 split across profiles must score each
    // candidate exactly as a direct run of the right profile would.
    let Some((orch, cfg)) = setup(DsoMode::Explicit) else { return };
    let profiles = orch.profiles().to_vec(); // tiny: [4, 8]
    assert_eq!(profiles, vec![4, 8]);
    let m = 12; // 8 + 4 exact split, no padding
    let (hist, cands) = inputs(&cfg, m, 3);
    let out = orch.submit(Arc::clone(&hist), &cands, m).expect("submit");
    assert_eq!(out.chunks, vec![8, 4]);
    assert_eq!(out.padding, 0);
    assert_eq!(out.scores.len(), m * cfg.n_tasks);

    // direct comparison: run the 8-profile engine on candidates 0..8
    let manifest = Manifest::load("artifacts").unwrap();
    let rt = Runtime::new().unwrap();
    let e8 = rt.load_engine(&manifest, &EngineKey::new("tiny", "fused", 8)).unwrap();
    let direct = e8.run(&hist, &cands[..8 * cfg.d_model]).unwrap();
    let diff = max_abs_diff(&out.scores[..8 * cfg.n_tasks], &direct);
    assert!(diff < 1e-5, "split chunk disagrees with direct run: {diff}");
}

#[test]
fn padding_stripped_and_scores_stable() {
    // m = 5 pads to 8; the 5 real scores must equal the unpadded prefix
    // of a direct 8-run with repeated-last-row padding.
    let Some((orch, cfg)) = setup(DsoMode::Explicit) else { return };
    let m = 5;
    let (hist, cands) = inputs(&cfg, m, 9);
    let out = orch.submit(Arc::clone(&hist), &cands, m).expect("submit");
    assert_eq!(out.scores.len(), m * cfg.n_tasks);
    assert_eq!(out.padding, 3);
    assert!(out.scores.iter().all(|s| (0.0..=1.0).contains(s)));
}

#[test]
fn implicit_mode_always_pads_to_max() {
    let Some((orch, cfg)) = setup(DsoMode::ImplicitPad) else { return };
    let (hist, cands) = inputs(&cfg, 4, 1);
    let out = orch.submit(hist, &cands, 4).expect("submit");
    assert_eq!(out.chunks, vec![8]);
    assert_eq!(out.padding, 4);
    // waste accounting reflects it
    assert!(orch.waste_fraction() > 0.4);
}

#[test]
fn explicit_wastes_less_than_implicit_on_mixed_m() {
    let Some((explicit, cfg)) = setup(DsoMode::Explicit) else { return };
    let Some((implicit, _)) = setup(DsoMode::ImplicitPad) else { return };
    for salt in 0..8u64 {
        let m = [4usize, 5, 8, 12][salt as usize % 4];
        let (h, c) = inputs(&cfg, m, salt);
        explicit.submit(Arc::clone(&h), &c, m).unwrap();
        implicit.submit(h, &c, m).unwrap();
    }
    assert!(
        explicit.waste_fraction() < implicit.waste_fraction(),
        "explicit {} vs implicit {}",
        explicit.waste_fraction(),
        implicit.waste_fraction()
    );
}

#[test]
fn concurrent_submissions_consistent() {
    let Some((orch, cfg)) = setup(DsoMode::Explicit) else { return };
    let orch = Arc::new(orch);
    // same request from 4 threads: identical scores
    let (hist, cands) = inputs(&cfg, 8, 5);
    let expected = orch.submit(Arc::clone(&hist), &cands, 8).unwrap().scores;
    let hs: Vec<_> = (0..4)
        .map(|_| {
            let orch = Arc::clone(&orch);
            let hist = Arc::clone(&hist);
            let cands = cands.clone();
            std::thread::spawn(move || orch.submit(hist, &cands, 8).unwrap().scores)
        })
        .collect();
    for h in hs {
        let got = h.join().unwrap();
        assert!(max_abs_diff(&got, &expected) < 1e-6);
    }
}

#[test]
fn zero_candidates_is_empty_ok() {
    let Some((orch, cfg)) = setup(DsoMode::Explicit) else { return };
    let (hist, _) = inputs(&cfg, 4, 0);
    let out = orch.submit(hist, &[], 0).unwrap();
    assert!(out.scores.is_empty());
    assert!(out.chunks.is_empty());
}

#[test]
fn mismatched_cands_len_rejected() {
    let Some((orch, cfg)) = setup(DsoMode::Explicit) else { return };
    let (hist, cands) = inputs(&cfg, 4, 0);
    assert!(orch.submit(hist, &cands[..cands.len() - 1], 4).is_err());
}
