//! Failure injection: the serving stack must degrade gracefully when the
//! remote feature service times out — stale/default features, never
//! failed requests (the accuracy/latency trade-off of §3.1 extends to
//! availability). Plus admission-control behaviour under overload.
//! No artifacts required.

use std::sync::Arc;
use std::time::Duration;

use flame::batching::RequestQueue;
use flame::config::{CacheMode, PdaConfig};
use flame::error::Error;
use flame::featurestore::{FeatureSchema, RemoteStore};
use flame::netsim::{Link, LinkConfig};
use flame::pda::engine::FetchClass;
use flame::pda::QueryEngine;

fn flaky_store(fail_rate: f64) -> (Arc<RemoteStore>, Arc<Link>) {
    let link = Arc::new(Link::new(LinkConfig {
        rtt: Duration::from_micros(200),
        bandwidth_bps: 1e9,
        jitter: 0.0,
        fail_rate,
    }));
    let store = Arc::new(RemoteStore::new(FeatureSchema::default(), Arc::clone(&link), 7));
    (store, link)
}

fn cfg(mode: CacheMode) -> PdaConfig {
    PdaConfig {
        cache_mode: mode,
        cache_capacity: 4096,
        cache_shards: 4,
        cache_ttl_ms: 60_000,
        refresh_workers: 1,
        ..PdaConfig::default()
    }
}

#[test]
fn sync_mode_survives_total_outage() {
    let (store, _) = flaky_store(1.0); // every remote call times out
    let engine = QueryEngine::new(&cfg(CacheMode::Sync), store);
    let out = engine.fetch(&[1, 2, 3]);
    assert_eq!(out.len(), 3);
    for (f, class) in &out {
        assert_eq!(*class, FetchClass::MissDefault);
        assert!(f.dense.iter().all(|&x| x == 0.0));
    }
    assert!(engine.store_errors.load(std::sync::atomic::Ordering::Relaxed) >= 1);
}

#[test]
fn sync_mode_serves_stale_during_outage() {
    // healthy first, then outage: previously-cached values must be served
    // stale rather than zeroed.
    let link_cfg_ok = LinkConfig {
        rtt: Duration::from_micros(200),
        bandwidth_bps: 1e9,
        jitter: 0.0,
        fail_rate: 0.0,
    };
    let link = Arc::new(Link::new(link_cfg_ok));
    let store = Arc::new(RemoteStore::new(FeatureSchema::default(), link, 7));
    let mut c = cfg(CacheMode::Sync);
    c.cache_ttl_ms = 1; // everything goes stale immediately
    let engine = QueryEngine::new(&c, Arc::clone(&store));
    let healthy = engine.fetch(&[42]);
    assert_eq!(healthy[0].1, FetchClass::Remote);
    std::thread::sleep(Duration::from_millis(5));

    // now a total-outage store sharing the same cache is what we model by
    // a new engine over a failing store; instead, flip to failing via a
    // second engine is not possible (cache is per-engine), so simulate
    // outage by swapping store: use a failing store and pre-warming its
    // cache through the public API.
    let (flaky, _) = flaky_store(1.0);
    let engine2 = QueryEngine::new(&c, flaky);
    // warm via insert path: a successful fetch is impossible, so push the
    // value through the cache directly (public cache handle)
    engine2.cache().insert(42, healthy[0].0.clone());
    std::thread::sleep(Duration::from_millis(5)); // let it expire
    let out = engine2.fetch(&[42]);
    assert_eq!(out[0].1, FetchClass::Stale, "stale fallback during outage");
    assert_eq!(out[0].0, healthy[0].0);
}

#[test]
fn async_mode_unaffected_by_outage_latency() {
    // async never blocks on the store, so an outage cannot raise request
    // latency — only freshness suffers.
    let (store, _) = flaky_store(1.0);
    let engine = QueryEngine::new(&cfg(CacheMode::Async), store);
    let t0 = std::time::Instant::now();
    for i in 0..50 {
        engine.fetch(&[i]);
    }
    assert!(
        t0.elapsed() < Duration::from_millis(50),
        "async fetch path blocked during outage: {:?}",
        t0.elapsed()
    );
    engine.drain_refreshes();
    // all refreshes failed; errors counted
    assert!(engine.store_errors.load(std::sync::atomic::Ordering::Relaxed) >= 1);
}

#[test]
fn async_recovers_after_outage_ends() {
    // fail_rate 0.5: retries eventually land and the cache fills.
    let (store, _) = flaky_store(0.5);
    let engine = QueryEngine::new(&cfg(CacheMode::Async), Arc::clone(&store));
    for round in 0..20 {
        engine.fetch(&[99]);
        engine.drain_refreshes();
        if let flame::cache::Lookup::Fresh(f) = engine.cache().get(99) {
            assert_eq!(f, store.fetch_one(99));
            return;
        }
        std::thread::sleep(Duration::from_millis(1 + round));
    }
    panic!("refresh never succeeded at 50% failure rate");
}

#[test]
fn partial_failure_rate_degrades_proportionally() {
    let (store, link) = flaky_store(0.3);
    let engine = QueryEngine::new(&cfg(CacheMode::Sync), store);
    let mut defaults = 0usize;
    for i in 0..200u64 {
        let out = engine.fetch(&[10_000 + i]); // all cold keys
        if out[0].1 == FetchClass::MissDefault {
            defaults += 1;
        }
    }
    let rate = defaults as f64 / 200.0;
    assert!((0.1..0.6).contains(&rate), "observed failure rate {rate}");
    assert!(link.queries_total() >= 200);
}

#[test]
fn queue_overload_sheds_not_blocks() {
    let q: Arc<RequestQueue<u64>> = RequestQueue::new(4);
    for i in 0..4 {
        q.push(i).unwrap();
    }
    let t0 = std::time::Instant::now();
    for _ in 0..100 {
        match q.push(99) {
            Err(Error::Overloaded(_)) => {}
            other => panic!("expected shed, got {other:?}"),
        }
    }
    assert!(t0.elapsed() < Duration::from_millis(50), "shedding must not block");
}

#[test]
fn timeout_costs_more_than_success() {
    // a timed-out transfer must be *slower* than a successful one (the
    // 3x penalty) — callers cannot profit from failure
    let (ok_store, _) = flaky_store(0.0);
    let (bad_store, _) = flaky_store(1.0);
    let t0 = std::time::Instant::now();
    let _ = ok_store.try_fetch_batch(&[1, 2, 3]);
    let ok_time = t0.elapsed();
    let t1 = std::time::Instant::now();
    let r = bad_store.try_fetch_batch(&[1, 2, 3]);
    let bad_time = t1.elapsed();
    assert!(r.is_err());
    assert!(bad_time > ok_time, "timeout {bad_time:?} vs ok {ok_time:?}");
}
