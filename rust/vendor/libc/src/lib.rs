//! Offline vendor stub: the subset of `libc` this repo uses
//! (`pda/numa.rs` topology detection and thread pinning on Linux).
//! Declarations bind directly against the platform C library, so the
//! behavior matches the real crate for these symbols.

#![allow(non_camel_case_types, non_snake_case)]

pub type c_int = i32;
pub type c_long = i64;
pub type pid_t = i32;

/// `sysconf` selector for the number of online processors (glibc value).
pub const _SC_NPROCESSORS_ONLN: c_int = 84;

/// Matches glibc's `cpu_set_t`: a 1024-bit (128-byte) CPU mask.
#[repr(C)]
#[derive(Copy, Clone)]
pub struct cpu_set_t {
    bits: [u64; 16],
}

pub fn CPU_ZERO(set: &mut cpu_set_t) {
    set.bits = [0; 16];
}

pub fn CPU_SET(cpu: usize, set: &mut cpu_set_t) {
    if cpu < 1024 {
        set.bits[cpu / 64] |= 1 << (cpu % 64);
    }
}

extern "C" {
    pub fn sysconf(name: c_int) -> c_long;
    pub fn sched_getcpu() -> c_int;
    pub fn sched_setaffinity(pid: pid_t, cpusetsize: usize, mask: *const cpu_set_t) -> c_int;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_set_ops() {
        // SAFETY-free: CPU_ZERO/CPU_SET are pure bit manipulation here.
        let mut set = cpu_set_t { bits: [u64::MAX; 16] };
        CPU_ZERO(&mut set);
        assert!(set.bits.iter().all(|&b| b == 0));
        CPU_SET(3, &mut set);
        CPU_SET(64, &mut set);
        assert_eq!(set.bits[0], 1 << 3);
        assert_eq!(set.bits[1], 1);
        CPU_SET(5000, &mut set); // out of range: ignored, no panic
    }

    #[test]
    fn sysconf_reports_cpus() {
        // SAFETY: sysconf with a valid selector has no preconditions.
        let n = unsafe { sysconf(_SC_NPROCESSORS_ONLN) };
        assert!(n >= 1);
    }
}
