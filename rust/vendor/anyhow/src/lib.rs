//! Offline vendor stub of `anyhow` — just the surface this repo uses:
//! [`Error`], [`Result`], the [`Context`] extension trait for `Result`
//! and `Option`, and the `anyhow!` / `bail!` macros. Context frames are
//! chained into the Display output like the real crate's `{:#}` form so
//! binary error messages stay informative.

use std::fmt;

/// Boxed dynamic error with a context chain (innermost cause last).
pub struct Error {
    /// Context frames, outermost first; the root cause is the last entry.
    chain: Vec<String>,
}

impl Error {
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    fn wrap<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The outermost message (mirrors `anyhow::Error::to_string`).
    pub fn root_cause_chain(&self) -> &[String] {
        &self.chain
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `fn main() -> Result<()>` prints the Debug form on error; make
        // it the readable chained message, like anyhow's report.
        write!(f, "{}", self.chain.join(": "))
    }
}

// Note: like the real anyhow, `Error` deliberately does NOT implement
// `std::error::Error` — that is what makes the blanket From below legal.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result` alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach human context to an error (`Result`) or absence (`Option`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).wrap(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_gone() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn context_chains_into_display() {
        let r: Result<()> = Err::<(), _>(io_gone()).context("loading manifest");
        let msg = r.unwrap_err().to_string();
        assert!(msg.contains("loading manifest") && msg.contains("gone"), "{msg}");
    }

    #[test]
    fn option_context() {
        let r: Result<u32> = None.context("flag missing");
        assert_eq!(r.unwrap_err().to_string(), "flag missing");
        let r: Result<u32> = Some(7).context("unused");
        assert_eq!(r.unwrap(), 7);
    }

    #[test]
    fn bail_and_question_mark() {
        fn inner(fail: bool) -> Result<u32> {
            if fail {
                bail!("failing with code {}", 3);
            }
            let v: u32 = "12".parse()?; // ParseIntError -> Error via From
            Ok(v)
        }
        assert_eq!(inner(false).unwrap(), 12);
        assert!(inner(true).unwrap_err().to_string().contains("code 3"));
    }
}
