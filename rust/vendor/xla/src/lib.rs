//! Offline vendor stub of the `xla` (xla-rs / PJRT) bindings.
//!
//! The container image this repo builds in has no XLA extension library
//! and no network, so the real bindings cannot link. This stub mirrors
//! the exact API surface `flame::runtime` uses, typechecks identically,
//! and fails fast at [`PjRtClient::cpu`] with an explanatory error. All
//! artifact/PJRT-dependent tests, benches, and examples gate on that
//! failure (or on `artifacts/` being absent) and skip cleanly, so the
//! pure-Rust stack — PDA, DSO planning, batching, cluster tier, workload
//! substrate — builds and tests green without a device runtime.

use std::fmt;
use std::path::Path;

/// PJRT-layer error.
#[derive(Debug)]
pub struct Error(String);

impl Error {
    fn unavailable(what: &str) -> Self {
        Error(format!(
            "{what}: PJRT runtime unavailable (offline `xla` vendor stub; \
             build against real xla-rs to execute engines)"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types transferable to the device (only f32 is used here).
pub trait ArrayElement: Copy {}
impl ArrayElement for f32 {}

/// A PJRT device handle (never constructed by the stub).
pub struct PjRtDevice;

/// A device-resident buffer (never constructed by the stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// A host-side literal (never constructed by the stub).
pub struct Literal;

impl Literal {
    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(Error::unavailable("Literal::to_tuple1"))
    }

    pub fn to_vec<T: ArrayElement>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("Literal::to_vec"))
    }
}

/// A compiled executable (never constructed by the stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

/// The PJRT client. `cpu()` is the single entry point; in the stub it
/// returns the unavailability error every caller gates on.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn buffer_from_host_buffer<T: ArrayElement>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer> {
        Err(Error::unavailable("PjRtClient::buffer_from_host_buffer"))
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

/// Parsed HLO module proto (never constructed by the stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        Err(Error::unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_reports_unavailable() {
        let e = PjRtClient::cpu().err().expect("stub must fail");
        assert!(e.to_string().contains("vendor stub"), "{e}");
    }
}
