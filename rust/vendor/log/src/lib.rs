//! Offline vendor stub of `log`: the five level macros, writing
//! level-prefixed lines to stderr for warn/error and discarding the
//! lower levels (no logger registry; serving telemetry goes through
//! `flame::metrics`, not the log crate).

#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => { eprintln!("[error] {}", format!($($arg)*)) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => { eprintln!("[warn] {}", format!($($arg)*)) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { { let _ = format_args!($($arg)*); } };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { { let _ = format_args!($($arg)*); } };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)*) => { { let _ = format_args!($($arg)*); } };
}
