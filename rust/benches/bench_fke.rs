//! Table 4 + Fig 12 — FKE ablation on the **native CPU engine**:
//! engine-construction levels (naive ≙ ONNX conversion, api ≙ TensorRT
//! API, fused ≙ + kernel fusion) measured as real FLOPs on a bare
//! checkout — no artifacts, no PJRT — at the scenario's native M, in
//! two launch modes (`--series` adds the Fig 12 per-profile throughput
//! series, api vs fused):
//!
//! * **solo** — one request, one history, one profile-shaped launch;
//! * **coalesced-mixed** — one packed batch whose rows come from three
//!   requests with three distinct histories (what the DSO coalescer
//!   produces), executed as ONE natively segmented launch.
//!
//! Default runs `base` and `long` at a capped transformer depth (every
//! layer is identical work, so the naive/api/fused ratios Table 4
//! measures are depth-invariant; `--full-depth` runs the configured
//! `layers_per_block`). `--smoke` shrinks to a CI-sized `base` run that
//! still *gates* on the fused-vs-naive ordering, on native segmentation
//! (executed rows == M for a 3-segment batch), and on packed-vs-solo
//! bit-identity — and every run emits machine-readable `BENCH_fke.json`.
//!
//! Absolute numbers are CPU, not A100/TensorRT — EXPERIMENTS.md compares
//! *shape* (ordering + rough factors), per DESIGN.md.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

use flame::benchkit::{table, BenchArgs, Bencher, Table};
use flame::config::Scenario;
use flame::dso::{ComputeBackend, SegmentBind};
use flame::fke::cpu::{CpuEngine, CpuEngineConfig, CpuModel};
use flame::fke::Variant;
use flame::util::json::Json;

const OUT_PATH: &str = "BENCH_fke.json";

struct VariantResult {
    variant: Variant,
    solo_ms: f64,
    mixed_ms: f64,
    pairs_per_s: f64,
    gflops_per_s: f64,
    flops_per_launch: u64,
    tiles_visited: u64,
    tiles_skipped: u64,
}

fn hist_for(len: usize, salt: u64) -> Vec<f32> {
    (0..len).map(|i| (((i as u64 + salt) * 31 % 113) as f32 / 113.0) - 0.5).collect()
}

fn cands_for(len: usize, salt: u64) -> Vec<f32> {
    (0..len).map(|i| (((i as u64 + salt) * 17 % 127) as f32 / 127.0) - 0.5).collect()
}

/// The coalesced-mixed segmentation: three requests' rows in one batch.
fn mixed_rows(m: usize) -> [usize; 3] {
    let a = m / 2;
    let b = m / 4;
    [a, b, m - a - b]
}

fn run_scenario(
    b: &mut Bencher,
    scenario: Scenario,
    depth: usize,
    threads: usize,
    smoke: bool,
) -> BTreeMap<String, Json> {
    let cfg = scenario.config();
    let m = cfg.native_m;
    let d = cfg.d_model;
    let model = CpuModel::with_depth(&cfg, CpuModel::seed_for(cfg.name.as_str()), depth)
        .expect("cpu model");
    println!(
        "\nFKE ablation — scenario '{}' (L={}, native M={m}, {} of {} layers x {} blocks, D={d}, {} threads)",
        cfg.name, cfg.seq_len, depth, cfg.layers_per_block, cfg.n_blocks, threads
    );

    let rows = mixed_rows(m);
    let hists: Vec<Vec<f32>> = (0..3).map(|i| hist_for(cfg.seq_len * d, 7 + i)).collect();
    let segs: Vec<Vec<f32>> =
        rows.iter().enumerate().map(|(i, &r)| cands_for(r * d, 100 + i as u64)).collect();
    let mut packed = Vec::new();
    for s in &segs {
        packed.extend_from_slice(s);
    }

    let mut results: Vec<VariantResult> = Vec::new();
    for variant in Variant::all() {
        let engine =
            CpuEngine::new(Arc::clone(&model), m, &CpuEngineConfig { variant, threads });
        let solo_hist = engine.upload_hist(&hists[0]).expect("upload");
        let seg_hists: Vec<_> =
            hists.iter().map(|h| engine.upload_hist(h).expect("upload")).collect();
        let solo_cands = cands_for(m * d, 5);

        // --- correctness gates (every variant, every run) ---
        // native segmentation: 3 segments execute M rows in one launch
        assert_eq!(
            engine.executed_rows_for(rows.len()),
            m,
            "{}: packed batch must execute M rows once, no per-history replay",
            engine.label()
        );
        // packed scores bit-identical to each request's solo launch
        let binds: Vec<SegmentBind<'_>> = seg_hists
            .iter()
            .zip(&rows)
            .map(|(h, &r)| SegmentBind { hist: h, rows: r })
            .collect();
        let packed_scores = engine.run_segmented(&binds, &packed).expect("mixed launch");
        let mut off = 0usize;
        for (i, (&r, seg)) in rows.iter().zip(&segs).enumerate() {
            let mut solo = seg.clone();
            let last = &seg[(r - 1) * d..r * d];
            for _ in 0..m - r {
                solo.extend_from_slice(last);
            }
            let sref = engine
                .run_segmented(&[SegmentBind { hist: &seg_hists[i], rows: m }], &solo)
                .expect("solo launch");
            assert_eq!(
                &packed_scores[off * cfg.n_tasks..(off + r) * cfg.n_tasks],
                &sref[..r * cfg.n_tasks],
                "{}: segment {i} diverged from its solo launch",
                engine.label()
            );
            off += r;
        }

        // per-launch analytic FLOPs (constant per variant + shape)
        let ks0 = engine.kernel_stats();
        engine
            .run_segmented(&[SegmentBind { hist: &solo_hist, rows: m }], &solo_cands)
            .expect("probe launch");
        let ks1 = engine.kernel_stats();
        let flops_per_launch = ks1.flops - ks0.flops;
        let tiles_visited = ks1.tiles_visited - ks0.tiles_visited;
        let tiles_skipped = ks1.tiles_skipped - ks0.tiles_skipped;

        // --- timing ---
        let solo = b
            .bench_with_items(
                &format!("fke/{}/{}/solo", cfg.name, variant.name()),
                Some(m as f64),
                || {
                    let out = engine
                        .run_segmented(&[SegmentBind { hist: &solo_hist, rows: m }], &solo_cands)
                        .expect("run");
                    std::hint::black_box(out);
                },
            )
            .expect("bench ran");
        let mixed = b
            .bench_with_items(
                &format!("fke/{}/{}/coalesced-mixed", cfg.name, variant.name()),
                Some(m as f64),
                || {
                    let binds: Vec<SegmentBind<'_>> = seg_hists
                        .iter()
                        .zip(&rows)
                        .map(|(h, &r)| SegmentBind { hist: h, rows: r })
                        .collect();
                    let out = engine.run_segmented(&binds, &packed).expect("run");
                    std::hint::black_box(out);
                },
            )
            .expect("bench ran");

        let solo_s = solo.mean.as_secs_f64();
        results.push(VariantResult {
            variant,
            solo_ms: solo_s * 1e3,
            mixed_ms: mixed.mean.as_secs_f64() * 1e3,
            pairs_per_s: solo.throughput().unwrap_or(0.0),
            gflops_per_s: flops_per_launch as f64 / 1e9 / solo_s.max(1e-12),
            flops_per_launch,
            tiles_visited,
            tiles_skipped,
        });
    }

    // --- Table 4 layout ---
    let mut t = Table::new(
        &format!(
            "Table 4 (reproduced, native CPU) — FKE ablation, scenario '{}' (M={m})",
            cfg.name
        ),
        &["Ablation Study", "Throughput", "Compute Latency", "Mixed-Batch Latency", "GFLOP/s"],
    );
    for r in &results {
        t.row(&[
            r.variant.paper_label().to_string(),
            table::kthroughput(r.pairs_per_s),
            table::ms(r.solo_ms),
            table::ms(r.mixed_ms),
            format!("{:.2}", r.gflops_per_s),
        ]);
    }
    let naive = &results[0];
    let fused = &results[results.len() - 1];
    let speedup = naive.solo_ms / fused.solo_ms.max(1e-12);
    let gain = fused.pairs_per_s / naive.pairs_per_s.max(1e-12);
    t.footnote(&format!(
        "speedup {} over baseline; throughput gain {} (paper: 4.6-6.1x / 4.7-6.3x on A100+TensorRT)",
        table::ratio(naive.solo_ms, fused.solo_ms),
        table::ratio(fused.pairs_per_s, naive.pairs_per_s),
    ));
    t.footnote(&format!(
        "fused mask schedule: {} tiles visited / {} skipped per launch ({:.0} % skipped); \
         coalesced-mixed = 3 requests, 3 histories, ONE launch of {m} rows",
        fused.tiles_visited,
        fused.tiles_skipped,
        fused.tiles_skipped as f64 / (fused.tiles_visited + fused.tiles_skipped).max(1) as f64
            * 100.0,
    ));
    t.print();

    // --- CI gate: the ablation ordering cannot bit-rot ---
    if smoke {
        assert!(
            fused.solo_ms < naive.solo_ms,
            "GATE: fused ({:.2} ms) must beat naive ({:.2} ms)",
            fused.solo_ms,
            naive.solo_ms
        );
    } else if speedup < 2.0 {
        eprintln!("  WARNING: fused speedup {speedup:.2}x below the 2x acceptance bar");
    }

    // --- Fig 12 series: per-profile throughput, api vs fused (the
    // paper's pairs/s-grows-with-M amortization plot) ---
    if b.args.series {
        println!("\nFig 12 (reproduced, native CPU) — throughput across candidate profiles");
        for variant in [Variant::Api, Variant::Fused] {
            // bench_with_items prints per-case summaries, so the series
            // line is buffered and emitted whole afterwards
            let mut line = String::new();
            for &pm in &cfg.m_profiles {
                let engine =
                    CpuEngine::new(Arc::clone(&model), pm, &CpuEngineConfig { variant, threads });
                let h = engine.upload_hist(&hists[0]).expect("upload");
                let cands = cands_for(pm * d, 11);
                if let Some(r) = b.bench_with_items(
                    &format!("fig12/{}/{}/m{pm}", cfg.name, variant.name()),
                    Some(pm as f64),
                    || {
                        let out = engine
                            .run_segmented(&[SegmentBind { hist: &h, rows: pm }], &cands)
                            .expect("run");
                        std::hint::black_box(out);
                    },
                ) {
                    line.push_str(&format!("  m{pm}={:.1}k", r.throughput().unwrap_or(0.0) / 1e3));
                }
            }
            println!("  {:<6}{line}", format!("{}:", variant.name()));
        }
    }

    // --- JSON ---
    let mut variants = BTreeMap::new();
    for r in &results {
        let mut o = BTreeMap::new();
        o.insert("solo_ms".into(), Json::Num(r.solo_ms));
        o.insert("mixed_ms".into(), Json::Num(r.mixed_ms));
        o.insert("pairs_per_s".into(), Json::Num(r.pairs_per_s));
        o.insert("gflops_per_s".into(), Json::Num(r.gflops_per_s));
        o.insert("flops_per_launch".into(), Json::Num(r.flops_per_launch as f64));
        o.insert("tiles_visited".into(), Json::Num(r.tiles_visited as f64));
        o.insert("tiles_skipped".into(), Json::Num(r.tiles_skipped as f64));
        variants.insert(r.variant.name().to_string(), Json::Obj(o));
    }
    let mut s = BTreeMap::new();
    s.insert("m".into(), Json::Num(m as f64));
    s.insert("depth".into(), Json::Num(depth as f64));
    s.insert("variants".into(), Json::Obj(variants));
    s.insert("speedup_fused_vs_naive".into(), Json::Num(speedup));
    s.insert("throughput_gain".into(), Json::Num(gain));
    s.insert("mixed_segments".into(), Json::Num(rows.len() as f64));
    s.insert("executed_rows_mixed".into(), Json::Num(m as f64));
    s.insert("replay_rows_emulated".into(), Json::Num((m * rows.len()) as f64));
    s.insert("score_identity".into(), Json::Str("bit-identical".into()));
    s
}

fn main() {
    let mut args = BenchArgs::from_env();
    let smoke = std::env::args().any(|a| a == "--smoke");
    let full_depth = std::env::args().any(|a| a == "--full-depth");
    let threads = {
        let argv: Vec<String> = std::env::args().collect();
        argv.iter()
            .position(|a| a == "--threads")
            .and_then(|i| argv.get(i + 1))
            .and_then(|s| s.parse::<usize>().ok())
            .unwrap_or(0)
    };
    let threads = if threads == 0 {
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1).min(8)
    } else {
        threads
    };
    if smoke {
        args.min_iters = 3;
        args.measure_time = Duration::from_millis(1);
        args.warmup_time = Duration::ZERO;
    }
    let scenarios: Vec<Scenario> = match &args.scenario {
        Some(name) => vec![Scenario::parse(name).expect("scenario")],
        None if smoke => vec![Scenario::Base],
        None => vec![Scenario::Base, Scenario::Long],
    };

    let mut b = Bencher::new(args);
    let mut scen_json = BTreeMap::new();
    let mut depth_used = 0usize;
    for scenario in scenarios {
        let cfg = scenario.config();
        let depth = if full_depth {
            cfg.layers_per_block
        } else if smoke {
            1
        } else {
            cfg.layers_per_block.min(2)
        };
        depth_used = depth;
        let s = run_scenario(&mut b, scenario, depth, threads, smoke);
        scen_json.insert(cfg.name, Json::Obj(s));
    }

    let mut top = BTreeMap::new();
    top.insert("bench".into(), Json::Str("fke".into()));
    top.insert("backend".into(), Json::Str("cpu-native".into()));
    top.insert("smoke".into(), Json::Bool(smoke));
    top.insert("threads".into(), Json::Num(threads as f64));
    top.insert("depth".into(), Json::Num(depth_used as f64));
    top.insert("scenarios".into(), Json::Obj(scen_json));
    match std::fs::write(OUT_PATH, Json::Obj(top).to_string()) {
        Ok(()) => eprintln!("  wrote {OUT_PATH}"),
        Err(e) => eprintln!("  could not write {OUT_PATH}: {e}"),
    }

    println!(
        "\nnote: throughput counts user-item pairs — larger M amortizes history compute \
         (paper §4.2.2); the mixed column is one natively segmented launch, so its rows \
         column-for-column match three solo launches bit-for-bit."
    );
}
