//! Table 4 + Fig 12 — FKE ablation: engine-construction levels
//! (naive ≙ ONNX conversion, api ≙ TensorRT API, fused ≙ + kernel
//! fusion) measured on pure model compute at the scenario's native M.
//!
//! Default runs the `bench` scenario (CI-speed); pass
//! `--scenario base` / `--scenario long` after `make artifacts-full` for
//! paper-scale rows. `--series` prints the Fig 12 per-profile series.
//!
//! Absolute numbers are CPU-PJRT, not A100/TensorRT — EXPERIMENTS.md
//! compares *shape* (ordering + rough factors), per DESIGN.md.

use flame::benchkit::{table, Bencher, Table};
use flame::manifest::Manifest;
use flame::runtime::{EngineKey, Runtime};

fn main() {
    let mut b = Bencher::from_env();
    let scenario = b.args.scenario.clone().unwrap_or_else(|| "bench".to_string());

    let manifest = match Manifest::load("artifacts") {
        Ok(m) if m.scenarios.contains_key(&scenario) => m,
        _ => {
            eprintln!("bench_fke: artifacts for '{scenario}' not built — run `make artifacts` (or artifacts-full for base/long); skipping");
            return;
        }
    };
    let rt = Runtime::new().expect("pjrt");
    let cfg = manifest.scenario(&scenario).unwrap().config.clone();
    let weights = rt.upload_weights(&manifest, &scenario).expect("weights");
    let m = cfg.native_m;

    println!("\nFKE ablation — scenario '{scenario}' (L={}, native M={m}, {} layers x {} blocks, D={})",
        cfg.seq_len, cfg.layers_per_block, cfg.n_blocks, cfg.d_model);

    let mut rows: Vec<(String, f64, f64, f64)> = Vec::new(); // label, tput, mean ms, p99 ms
    for variant in ["naive", "api", "fused"] {
        if manifest.find(&scenario, variant, m).is_err() {
            eprintln!("  (skipping {variant}: not lowered at m{m})");
            continue;
        }
        let key = EngineKey::new(&scenario, variant, m);
        eprintln!("  compiling {} ...", key.label());
        let engine = rt
            .load_engine_with_weights(&manifest, &key, std::sync::Arc::clone(&weights))
            .expect("engine");
        let hist: Vec<f32> = (0..engine.hist_len()).map(|i| ((i % 31) as f32 / 31.0) - 0.5).collect();
        let cands: Vec<f32> = (0..engine.cands_len()).map(|i| ((i % 29) as f32 / 29.0) - 0.5).collect();

        let label = flame::fke::Variant::parse(variant).unwrap().paper_label();
        let r = b
            .bench_with_items(&format!("fke/{scenario}/{variant}"), Some(m as f64), || {
                let out = engine.run(&hist, &cands).expect("run");
                std::hint::black_box(out);
            })
            .expect("bench ran");
        rows.push((
            label.to_string(),
            r.throughput().unwrap_or(0.0),
            r.mean.as_secs_f64() * 1e3,
            r.p99.as_secs_f64() * 1e3,
        ));
    }

    // Table 4 layout
    let mut t = Table::new(
        &format!("Table 4 (reproduced) — FKE ablation, scenario '{scenario}' (M={m})"),
        &["Ablation Study", "Throughput", "Compute Latency", "P99 Compute Latency"],
    );
    for (label, tput, mean, p99) in &rows {
        t.row(&[
            label.clone(),
            table::kthroughput(*tput),
            table::ms(*mean),
            table::ms(*p99),
        ]);
    }
    if rows.len() >= 2 {
        t.footnote(&format!(
            "speedup {} over baseline; throughput gain {} (paper: 4.6-6.1x / 4.7-6.3x on A100+TensorRT)",
            table::ratio(rows[0].2, rows[rows.len() - 1].2),
            table::ratio(rows[rows.len() - 1].1, rows[0].1),
        ));
    }
    t.footnote("throughput in thousands of user-item pairs/s; CPU-PJRT testbed — compare shape, not absolutes");
    t.print();

    // Fig 12 series: per-profile throughput for api vs fused
    if b.args.series {
        println!("\nFig 12 (reproduced) — throughput series across candidate profiles");
        for variant in ["api", "fused"] {
            let profiles = manifest.profiles_for(&scenario, variant);
            print!("  {variant:<6}:");
            for pm in profiles {
                let key = EngineKey::new(&scenario, variant, pm);
                let engine = rt
                    .load_engine_with_weights(&manifest, &key, std::sync::Arc::clone(&weights))
                    .expect("engine");
                let hist: Vec<f32> = vec![0.1; engine.hist_len()];
                let cands: Vec<f32> = vec![0.05; engine.cands_len()];
                if let Some(r) = b.bench_with_items(
                    &format!("fig12/{scenario}/{variant}/m{pm}"),
                    Some(pm as f64),
                    || {
                        std::hint::black_box(engine.run(&hist, &cands).expect("run"));
                    },
                ) {
                    print!("  m{pm}={:.1}k", r.throughput().unwrap_or(0.0) / 1e3);
                }
            }
            println!();
        }
    }

    // the paper's amortization observation: pairs/s grows with M
    if rows.len() >= 2 {
        println!("\nnote: throughput counts user-item pairs — larger M amortizes history compute (paper §4.2.2).");
    }
}
