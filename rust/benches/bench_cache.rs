//! Micro-bench: cache substrate ablations — shard count vs contention
//! (the paper's "divided into multiple buckets to reduce write lock
//! collisions"), plus raw LRU op cost. No artifacts needed.

use std::sync::Arc;
use std::time::Duration;

use flame::benchkit::Bencher;
use flame::cache::ShardedCache;
use flame::util::rng::{Rng, Zipf};

fn contention_run(shards: usize, threads: usize, ops: usize) -> Duration {
    let cache: Arc<ShardedCache<u64>> =
        Arc::new(ShardedCache::new(64 * 1024, shards, Duration::from_secs(60)));
    let zipf = Zipf::new(100_000, 1.0);
    let t0 = std::time::Instant::now();
    std::thread::scope(|s| {
        for t in 0..threads {
            let cache = Arc::clone(&cache);
            let zipf = zipf.clone();
            s.spawn(move || {
                let mut rng = Rng::new(t as u64 + 1);
                for i in 0..ops {
                    let k = zipf.sample(&mut rng);
                    if i % 4 == 0 {
                        cache.insert(k, k);
                    } else {
                        let _ = cache.get(k);
                    }
                }
            });
        }
    });
    t0.elapsed()
}

fn main() {
    let mut b = Bencher::from_env();

    // single-thread op costs
    let cache: ShardedCache<u64> = ShardedCache::new(64 * 1024, 16, Duration::from_secs(60));
    for k in 0..10_000u64 {
        cache.insert(k, k);
    }
    let mut rng = Rng::new(3);
    b.bench("cache/get_hit", || {
        let k = rng.below(10_000);
        std::hint::black_box(cache.get(k));
    });
    b.bench("cache/get_miss", || {
        let k = 1_000_000 + rng.below(10_000);
        std::hint::black_box(cache.get(k));
    });
    b.bench("cache/insert", || {
        let k = rng.below(1_000_000);
        cache.insert(k, k);
    });

    // contention ablation: 1 vs 16 shards under 8 threads (Zipf keys —
    // the hot head is exactly what collides)
    println!("\nshard-count contention ablation (8 threads, 200k ops each, Zipf 1.0):");
    for shards in [1usize, 4, 16, 64] {
        let d = contention_run(shards, 8, 200_000);
        println!(
            "  shards {shards:>3}: {:>8.1} ms total ({:.1} M ops/s)",
            d.as_secs_f64() * 1e3,
            8.0 * 200_000.0 / d.as_secs_f64() / 1e6
        );
    }
    println!("\n(single-bucket locks serialize the Zipf head; sharding restores scaling — §3.1)");
}
