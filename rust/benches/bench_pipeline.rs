//! Decoupled two-stage pipeline ablation: synchronous serve vs.
//! pipelined (feature/compute overlap) vs. pipelined + feature-miss
//! coalescing, under Zipf-hot candidate traffic with short feature TTLs
//! (so hot ids keep missing and the coalescer has duplicates to pack).
//!
//! Artifact-free by design — compute runs on the deterministic
//! [`SimEngine`] backend with a fixed per-launch delay, so the bench
//! exercises the full serve path (PDA fetch → assembly → handoff → DSO
//! split/launch → response) on any bare checkout; the real-engine
//! pipeline is driven via `flame serve --pipeline`.
//!
//! Every run emits machine-readable `BENCH_pipeline.json` — arms ×
//! {p50/p99 latency, request + pair throughput, link MB/s, remote store
//! queries, handoff wait, busy-overlap ratio} plus the score-identity
//! verdict — so the repo's bench trajectory has diffable data.
//!
//! `--smoke` shrinks the run to a CI-sized check (sub-second arms) that
//! still asserts bit-identical scores across all three arms and writes
//! the JSON, so the ablation cannot bit-rot.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

use flame::benchkit::{table, BenchArgs, Table};
use flame::config::{CacheMode, ModelConfig, StackConfig, WorkloadConfig};
use flame::dso::{ComputeBackend, SimEngine};
use flame::netsim::{Link, LinkConfig};
use flame::pda::StagingArena;
use flame::server::pipeline::StackBuilder;
use flame::server::ServingStack;
use flame::util::json::Json;
use flame::workload::{Generator, MDist, Request};

const SEQ: usize = 32;
const D: usize = 16;
const TASKS: usize = 3;
const PROFILES: [usize; 4] = [16, 32, 64, 128];
const SEED: u64 = 2026;
const OUT_PATH: &str = "BENCH_pipeline.json";

/// Per-launch simulated engine time — roughly the tiny-profile PJRT
/// launch cost on the CPU testbed, so stage overlap has real compute to
/// hide.
const COMPUTE_DELAY: Duration = Duration::from_micros(900);

struct Arm {
    label: &'static str,
    pipeline: bool,
    fetch_coalesce: bool,
}

const ARMS: [Arm; 3] = [
    Arm { label: "sync", pipeline: false, fetch_coalesce: false },
    Arm { label: "pipelined", pipeline: true, fetch_coalesce: false },
    Arm { label: "pipelined+fetch-coalesce", pipeline: true, fetch_coalesce: true },
];

struct ArmResult {
    label: String,
    requests_per_s: f64,
    pairs_per_s: f64,
    p50_ms: f64,
    p99_ms: f64,
    link_mb_per_s: f64,
    remote_queries: u64,
    handoff_mean_ms: f64,
    fetch_riders: u64,
    /// (Σ feature busy + Σ compute busy) / wall — > 1.0 per worker-pair
    /// means the stages genuinely overlapped.
    busy_overlap: f64,
    arena_growths: u64,
}

fn model_cfg() -> ModelConfig {
    ModelConfig {
        name: "sim".into(),
        seq_len: SEQ,
        n_blocks: 1,
        layers_per_block: 1,
        d_model: D,
        n_heads: 1,
        n_tasks: TASKS,
        m_profiles: PROFILES.to_vec(),
        native_m: PROFILES[PROFILES.len() - 1],
    }
}

fn build(arm: &Arm) -> (Arc<ServingStack>, Arc<Link>) {
    let link = Arc::new(Link::new(LinkConfig {
        rtt: Duration::from_micros(400),
        bandwidth_bps: 200e6,
        jitter: 0.0,
        fail_rate: 0.0,
    }));
    let mut cfg = StackConfig::default();
    cfg.pda.cache_mode = CacheMode::Sync;
    cfg.pda.cache_ttl_ms = 50; // hot ids keep expiring: sustained misses
    cfg.pda.numa_binding = false;
    cfg.pda.fetch_coalesce = arm.fetch_coalesce;
    cfg.pda.fetch_wait_us = 200;
    cfg.server.pipeline = arm.pipeline;
    // thread parity: 4 serve threads either way
    cfg.server.pipeline_workers = if arm.pipeline { 2 } else { 4 };
    cfg.server.feature_workers = 2;
    cfg.server.handoff_capacity = 8;
    let backends: Vec<Arc<dyn ComputeBackend>> = PROFILES
        .iter()
        .map(|&m| {
            Arc::new(SimEngine::new(m, SEQ, D, TASKS).with_delay(COMPUTE_DELAY))
                as Arc<dyn ComputeBackend>
        })
        .collect();
    let stack = Arc::new(
        StackBuilder::new("sim", "sim", cfg)
            .with_link(Arc::clone(&link))
            .build_from_backends(model_cfg(), SEED, backends)
            .expect("sim stack"),
    );
    (stack, link)
}

fn workload(n: usize) -> Vec<Request> {
    let wl = WorkloadConfig {
        catalog_size: 50_000,
        zipf_theta: 1.1, // hot-item skew: concurrent requests share ids
        n_users: 5_000,
        candidate_mix: MDist::Zipf.mix(&PROFILES),
        arrival_rate: None,
        seed: SEED,
    };
    Generator::new(&wl, SEQ).batch(n)
}

/// Bit-identity gate: the same requests through this arm and through a
/// fresh synchronous stack must score identically (same store/embedding
/// seeds; sync cache mode is deterministic).
fn check_score_identity(arm: &Arm, probe: &[Request]) {
    let (sync_stack, _) = build(&ARMS[0]);
    let mut arena = StagingArena::new(sync_stack.arena_capacity());
    let expected: Vec<Vec<f32>> = probe
        .iter()
        .map(|r| sync_stack.serve(r, &mut arena).expect("sync serve").scores)
        .collect();
    let (stack, _) = build(arm);
    let got: Vec<Vec<f32>> = if arm.pipeline {
        let handle = stack.spawn_pipeline();
        let scores = probe
            .iter()
            .map(|r| handle.serve(r).expect("pipelined serve").scores)
            .collect();
        handle.shutdown();
        scores
    } else {
        let mut arena = StagingArena::new(stack.arena_capacity());
        probe.iter().map(|r| stack.serve(r, &mut arena).expect("serve").scores).collect()
    };
    for (i, (e, g)) in expected.iter().zip(&got).enumerate() {
        assert_eq!(e, g, "arm '{}' diverged from sync scores on probe request {i}", arm.label);
    }
}

fn run_arm(arm: &Arm, requests: &[Request], seconds: f64) -> ArmResult {
    let (stack, link) = build(arm);
    let drivers = 8;

    // warmup: engine + cache first-touch costs out of the window
    let warm = &requests[..64.min(requests.len())];
    if arm.pipeline {
        let handle = stack.spawn_pipeline();
        handle.drive_closed_loop(warm, drivers, Duration::from_secs(30));
        handle.shutdown();
    } else {
        stack.drive_closed_loop(warm, 4, Duration::from_secs(30));
    }
    // histograms reset after warmup; monotone counters are
    // baseline-subtracted instead so the report covers the measured
    // window only
    stack.metrics.overall.reset();
    stack.metrics.compute.reset();
    stack.metrics.feature.reset();
    stack.metrics.handoff.reset();
    let pairs0 = stack.metrics.pairs();
    let requests0 = stack.metrics.requests();
    let bytes0 = link.bytes_total();
    let queries0 = link.queries_total();
    let riders0 = stack.query.fetch_coalesce_stats().riders;
    let growths0 = stack.metrics.arena_growths();

    let t0 = std::time::Instant::now();
    if arm.pipeline {
        let handle = stack.spawn_pipeline();
        handle.drive_closed_loop(&requests[64..], drivers, Duration::from_secs_f64(seconds));
        handle.shutdown();
    } else {
        stack.drive_closed_loop(&requests[64..], 4, Duration::from_secs_f64(seconds));
    }
    let elapsed = t0.elapsed().as_secs_f64();

    let served = (stack.metrics.requests() - requests0) as f64;
    let pairs = (stack.metrics.pairs() - pairs0) as f64;
    let snap = stack.metrics.snapshot_over(elapsed);
    let busy_us = (snap.feature_mean_ms + snap.compute_mean_ms) * 1e3 * served;
    let fs = stack.query.fetch_coalesce_stats();
    ArmResult {
        label: arm.label.to_string(),
        requests_per_s: served / elapsed,
        pairs_per_s: pairs / elapsed,
        p50_ms: snap.overall_p50_ms,
        p99_ms: snap.overall_p99_ms,
        link_mb_per_s: (link.bytes_total() - bytes0) as f64 / 1e6 / elapsed,
        remote_queries: link.queries_total() - queries0,
        handoff_mean_ms: snap.handoff_mean_ms,
        fetch_riders: fs.riders - riders0,
        busy_overlap: busy_us / (elapsed * 1e6).max(1e-9),
        arena_growths: snap.arena_growths - growths0,
    }
}

fn emit_json(results: &[ArmResult], smoke: bool) {
    let mut arms = BTreeMap::new();
    for r in results {
        let mut o = BTreeMap::new();
        o.insert("requests_per_s".into(), Json::Num(r.requests_per_s));
        o.insert("pairs_per_s".into(), Json::Num(r.pairs_per_s));
        o.insert("p50_ms".into(), Json::Num(r.p50_ms));
        o.insert("p99_ms".into(), Json::Num(r.p99_ms));
        o.insert("link_mb_per_s".into(), Json::Num(r.link_mb_per_s));
        o.insert("remote_queries".into(), Json::Num(r.remote_queries as f64));
        o.insert("handoff_mean_ms".into(), Json::Num(r.handoff_mean_ms));
        o.insert("fetch_riders".into(), Json::Num(r.fetch_riders as f64));
        o.insert("busy_overlap".into(), Json::Num(r.busy_overlap));
        o.insert("arena_growths".into(), Json::Num(r.arena_growths as f64));
        arms.insert(r.label.clone(), Json::Obj(o));
    }
    let mut top = BTreeMap::new();
    top.insert("bench".into(), Json::Str("pipeline".into()));
    top.insert("smoke".into(), Json::Bool(smoke));
    top.insert("score_identity".into(), Json::Str("bit-identical".into()));
    top.insert("arms".into(), Json::Obj(arms));
    let doc = Json::Obj(top);
    match std::fs::write(OUT_PATH, doc.to_string()) {
        Ok(()) => eprintln!("  wrote {OUT_PATH}"),
        Err(e) => eprintln!("  could not write {OUT_PATH}: {e}"),
    }
}

fn main() {
    let args = BenchArgs::from_env();
    let smoke = std::env::args().any(|a| a == "--smoke");
    let seconds = if smoke { 0.4 } else { args.measure_time.as_secs_f64().max(3.0) };
    let n_requests = if smoke { 2_000 } else { 100_000 };

    println!(
        "\nPipeline ablation — sim backend, {seconds:.1}s per arm, compute {}µs/launch{}",
        COMPUTE_DELAY.as_micros(),
        if smoke { " [smoke]" } else { "" }
    );

    let requests = workload(n_requests);
    let probe = &requests[..24];
    let mut results = Vec::new();
    for arm in &ARMS {
        if !args.wants(arm.label) {
            continue;
        }
        eprintln!("  [{}] score-identity probe ...", arm.label);
        check_score_identity(arm, probe);
        eprintln!("  [{}] measuring ...", arm.label);
        let r = run_arm(arm, &requests, seconds);
        eprintln!(
            "  [{}] {:.0} req/s, p50 {:.2} ms, {} remote queries, overlap {:.2}",
            r.label, r.requests_per_s, r.p50_ms, r.remote_queries, r.busy_overlap
        );
        results.push(r);
    }

    let mut t = Table::new(
        "Decoupled pipeline ablation (sim backend, Zipf traffic, 50ms feature TTL)",
        &[
            "Arm",
            "Requests/s",
            "Throughput",
            "P50",
            "P99",
            "Handoff",
            "Link MB/s",
            "Remote Queries",
            "Overlap",
        ],
    );
    for r in &results {
        t.row(&[
            r.label.clone(),
            format!("{:.0}", r.requests_per_s),
            table::kthroughput(r.pairs_per_s),
            table::ms(r.p50_ms),
            table::ms(r.p99_ms),
            table::ms(r.handoff_mean_ms),
            format!("{:.2}", r.link_mb_per_s),
            r.remote_queries.to_string(),
            format!("{:.2}", r.busy_overlap),
        ]);
    }
    let find = |l: &str| results.iter().find(|r| r.label == l);
    if let (Some(sync), Some(pipe)) = (find("sync"), find("pipelined")) {
        t.footnote(&format!(
            "pipelined vs sync: {} request throughput; busy-overlap {:.2} vs {:.2} \
             (> per-thread share proves feature/compute overlap)",
            table::ratio(pipe.requests_per_s, sync.requests_per_s),
            pipe.busy_overlap,
            sync.busy_overlap,
        ));
    }
    if let (Some(pipe), Some(co)) = (find("pipelined"), find("pipelined+fetch-coalesce")) {
        t.footnote(&format!(
            "fetch coalescer: {} -> {} remote queries ({} rider ids shared in-flight fetches)",
            pipe.remote_queries, co.remote_queries, co.fetch_riders,
        ));
        if !smoke && co.remote_queries >= pipe.remote_queries {
            eprintln!(
                "  WARNING: coalescer did not reduce remote queries ({} vs {})",
                co.remote_queries, pipe.remote_queries
            );
        }
    }
    t.footnote("scores verified bit-identical to the synchronous path in every arm");
    t.print();
    emit_json(&results, smoke);
}
