//! Table 3 — PDA ablation on the full serving stack under Zipf bypass-
//! style traffic: (-Cache,-MemOpt) / (+Cache,-MemOpt) / Full PDA.
//!
//! "Mem Opt" = NUMA-affinity worker pinning + staging arenas (the
//! pinned-transfer analogue). Metrics are the paper's columns:
//! throughput (k user-item pairs/s), overall latency, P99, network MB/s.

use std::sync::Arc;
use std::time::Duration;

use flame::benchkit::{table, BenchArgs, Table};
use flame::config::{PdaConfig, StackConfig, WorkloadConfig};
use flame::manifest::Manifest;
use flame::netsim::{Link, LinkConfig};
use flame::runtime::Runtime;
use flame::server::pipeline::StackBuilder;
use flame::workload::Generator;

struct Arm {
    label: &'static str,
    pda: PdaConfig,
}

fn main() {
    let args = BenchArgs::from_env();
    let scenario = args.scenario.clone().unwrap_or_else(|| "bench".to_string());
    let seconds = (args.measure_time.as_secs_f64() * 2.0).max(6.0);
    // One worker per CPU core, minimum 1: the paper's Table 3 holds CPU
    // load well below saturation (~16%), so feature latency is exposed
    // rather than hidden behind compute overlap. Oversubscribing workers
    // on a small host would mask exactly the effect being measured.
    let workers = (flame::pda::numa::num_cpus() / 2).max(1);

    let manifest = match Manifest::load("artifacts") {
        Ok(m) if m.scenarios.contains_key(&scenario) => m,
        _ => {
            eprintln!("bench_pda: artifacts for '{scenario}' missing — run `make artifacts`; skipping");
            return;
        }
    };

    let arms = [
        Arm { label: "-Cache, -Mem Opt", pda: PdaConfig::baseline() },
        Arm { label: "+Cache, -Mem Opt", pda: PdaConfig::cache_only() },
        Arm { label: "+Cache, +Mem Opt (Full PDA)", pda: PdaConfig::default() },
    ];

    println!("\nPDA ablation — scenario '{scenario}', {workers} pipeline workers, {seconds:.0}s per arm");
    let mut rows = Vec::new();
    for arm in &arms {
        if !args.wants(arm.label) {
            continue;
        }
        let rt = Runtime::new().expect("pjrt");
        let mut cfg = StackConfig::default();
        cfg.pda = arm.pda.clone();
        cfg.server.pipeline_workers = workers;

        let link = Arc::new(Link::new(LinkConfig::default()));
        eprintln!("  [{}] building stack ...", arm.label);
        let stack = Arc::new(
            StackBuilder::new(&scenario, "fused", cfg.clone())
                .with_link(Arc::clone(&link))
                .build(&rt, &manifest)
                .expect("stack"),
        );

        // fixed-M traffic (the PDA test isolates the feature path; the
        // paper holds model load constant across arms)
        let wl = WorkloadConfig {
            catalog_size: 100_000,
            zipf_theta: 1.0,
            n_users: 10_000,
            candidate_mix: vec![(stack.model_cfg.native_m.min(stack.orchestrator.max_profile()), 1.0)],
            arrival_rate: None,
            seed: 77,
        };
        let mut gen = Generator::new(&wl, stack.model_cfg.seq_len);
        let requests = gen.batch(100_000);

        // warmup (closed loop, one request in flight per worker)
        stack.drive_closed_loop(&requests[..48], workers, Duration::from_secs(30));
        stack.query.drain_refreshes();
        stack.metrics.overall.reset();
        let pairs0 = stack.metrics.pairs();
        let bytes0 = link.bytes_total();

        let t0 = std::time::Instant::now();
        stack.drive_closed_loop(&requests[48..], workers, Duration::from_secs_f64(seconds));
        let elapsed = t0.elapsed().as_secs_f64();

        let pairs = (stack.metrics.pairs() - pairs0) as f64;
        let mb_s = (link.bytes_total() - bytes0) as f64 / 1e6 / elapsed;
        let snap = stack.metrics.snapshot_over(elapsed);
        rows.push((
            arm.label,
            pairs / elapsed,
            snap.overall_mean_ms,
            snap.overall_p99_ms,
            mb_s,
            stack.query.cache().stats.hit_rate(),
        ));
        eprintln!(
            "  [{}] {:.1}k pairs/s, {:.2} ms mean, hit {:.0}%",
            arm.label,
            pairs / elapsed / 1e3,
            snap.overall_mean_ms,
            stack.query.cache().stats.hit_rate() * 100.0
        );
    }

    let mut t = Table::new(
        &format!("Table 3 (reproduced) — PDA ablation, scenario '{scenario}'"),
        &["Ablation Study", "Throughput", "Overall Latency", "P99 Overall Latency", "Network Utilization", "Cache Hit"],
    );
    for (label, tput, mean, p99, mb, hit) in &rows {
        t.row(&[
            label.to_string(),
            table::kthroughput(*tput),
            table::ms(*mean),
            table::ms(*p99),
            format!("{mb:.1} MB/s"),
            format!("{:.0} %", hit * 100.0),
        ]);
    }
    if rows.len() == 3 {
        t.footnote(&format!(
            "full PDA vs baseline: {} throughput, {} latency (paper: 1.9x / 1.7x)",
            table::ratio(rows[2].1, rows[0].1),
            table::ratio(rows[0].2, rows[2].2),
        ));
    }
    t.footnote("throughput in thousands of user-item pairs/s; simulated remote feature link (DESIGN.md)");
    t.print();
}
