//! Cluster-tier bench: routing-policy ablation over N simulated
//! replicas under the paper's non-uniform candidate mix (most requests
//! small-M, a heavy tail of large-M), plus an overload phase that
//! exercises deadline-aware admission. No artifacts needed.
//!
//! Reported per policy: throughput (user-item pairs/s), p99 latency,
//! shed / SLA-miss counts, and the per-replica + aggregate feature-cache
//! hit rate. The headline effect: cache-affinity consistent hashing
//! keeps each replica's user-feature cache warm for returning users, so
//! its aggregate hit rate strictly beats round-robin's.

use std::sync::Arc;
use std::time::{Duration, Instant};

use flame::benchkit::Table;
use flame::cluster::{
    ClusterConfig, ClusterRouter, ReplicaBackend, ResultCacheConfig, RoutePolicy, SimConfig,
    SimReplica,
};
use flame::config::WorkloadConfig;
use flame::workload::{driver, Generator, Request};

const REPLICAS: usize = 3;
const USERS: u64 = 1_500;
const REQUESTS: usize = 9_000;
const CONCURRENCY: usize = 24;

fn build_router(policy: RoutePolicy, deadline_ms: u64, sim: SimConfig) -> Arc<ClusterRouter> {
    let slots = sim.slots;
    let backends: Vec<Arc<dyn ReplicaBackend>> = (0..REPLICAS)
        .map(|_| Arc::new(SimReplica::new(sim.clone())) as Arc<dyn ReplicaBackend>)
        .collect();
    let cfg = ClusterConfig {
        policy,
        deadline_ms,
        slots_per_replica: slots,
        ..ClusterConfig::default()
    };
    Arc::new(ClusterRouter::new(backends, cfg).expect("router"))
}

fn requests() -> Vec<Request> {
    let wl = WorkloadConfig {
        catalog_size: 100_000,
        zipf_theta: 0.99,
        n_users: USERS,
        // non-uniform M distribution (Table 5 style): small requests
        // dominate, large-M tail carries most of the pair volume
        candidate_mix: vec![(128, 0.55), (256, 0.25), (512, 0.15), (1024, 0.05)],
        arrival_rate: None,
        seed: 17,
    };
    Generator::new(&wl, 32).batch(REQUESTS)
}

fn main() {
    println!(
        "cluster routing-policy ablation: {REPLICAS} replicas, {USERS} users, \
         {REQUESTS} requests, non-uniform M mix [128x.55 256x.25 512x.15 1024x.05]"
    );

    let reqs = requests();
    let mut agg_hit = std::collections::HashMap::new();

    let mut table = Table::new(
        "closed-loop policy comparison",
        &[
            "policy",
            "throughput",
            "p99",
            "shed",
            "sla miss",
            "agg hit %",
            "per-replica hit %",
        ],
    );
    for policy in RoutePolicy::all() {
        let router = build_router(policy, 50, SimConfig::default());
        let t0 = Instant::now();
        let report = driver::closed_loop(reqs.clone(), CONCURRENCY, Duration::from_secs(120), |r| {
            router.submit(r).is_ok()
        });
        let elapsed = t0.elapsed().as_secs_f64();
        let snap = router.snapshot();
        let agg = router.metrics.snapshot_over(elapsed);
        let per_replica: Vec<String> = snap
            .replicas
            .iter()
            .map(|r| format!("{:.0}", r.cache_hit_rate * 100.0))
            .collect();
        table.row(&[
            policy.name().to_string(),
            format!("{:.0} k pairs/s", agg.throughput_pairs_per_s / 1e3),
            format!("{:.2} ms", agg.overall_p99_ms),
            snap.shed.to_string(),
            snap.sla_misses.to_string(),
            format!("{:.1}", snap.aggregate_cache_hit_rate * 100.0),
            per_replica.join(" / "),
        ]);
        agg_hit.insert(policy.name(), snap.aggregate_cache_hit_rate);
        assert_eq!(
            report.completed + report.rejected,
            report.submitted,
            "driver accounting"
        );
    }
    table.footnote("per-replica user-feature caches; hit rate = hits / lookups");
    table.footnote("shed = deadline admission refusals; sla miss = completed past budget");
    table.print();

    let aff = agg_hit["cache-affinity"];
    let rr = agg_hit["round-robin"];
    println!(
        "\ncache-affinity vs round-robin aggregate hit rate: {:.1}% vs {:.1}% — {}",
        aff * 100.0,
        rr * 100.0,
        if aff > rr { "affinity strictly higher ✓" } else { "UNEXPECTED: affinity not higher" }
    );

    // ---- overload phase: deadline admission under saturation ----
    // 3 replicas x 1 slot x ~2.2 ms service ≈ 1.4 k req/s capacity,
    // driven open-loop at 4 k req/s with a 6 ms budget: the router must
    // shed most of the excess at the front door.
    let overload_sim = SimConfig {
        base_us: 2_000,
        per_pair_ns: 0,
        miss_penalty_us: 200,
        slots: 1,
        ..SimConfig::default()
    };
    println!("\noverload: open-loop 4000 req/s vs ~1.4k req/s capacity, 6 ms budget");
    let mut otable = Table::new(
        "deadline admission under overload",
        &["policy", "submitted", "completed", "shed", "sla miss", "rerouted"],
    );
    for policy in RoutePolicy::all() {
        let router = build_router(policy, 6, overload_sim.clone());
        let report = driver::open_loop_cluster(
            &router,
            reqs.clone(),
            4_000.0,
            Duration::from_secs(1),
            256,
            5,
            0.0,
        );
        let snap = router.snapshot();
        otable.row(&[
            policy.name().to_string(),
            report.submitted.to_string(),
            report.completed.to_string(),
            snap.shed.to_string(),
            snap.sla_misses.to_string(),
            snap.rerouted.to_string(),
        ]);
    }
    otable.footnote("shed requests cost nothing downstream — the SLA-protecting trade");
    otable.print();

    // ---- result-cache ablation: off / cache / cache+single-flight ----
    // Duplicate bursts (the upstream retriever re-issuing a candidate
    // set) are where the router's result tier earns its keep: a cached
    // duplicate skips the replica entirely, and single-flight coalesces
    // concurrent duplicates onto one backend serve.
    println!(
        "\nresult-cache ablation: {REPLICAS} replicas, cache-affinity, \
         duplicate rates 0% / 10% / 30%"
    );
    let mut ctable = Table::new(
        "router result cache under duplicate traffic",
        &["arm", "dup %", "throughput", "p99", "backend serves", "hits", "coalesced"],
    );
    // serves[(arm, dup%)] for the cross-arm comparisons below
    let mut serves = std::collections::HashMap::new();
    let mut speed = std::collections::HashMap::new();
    for &dup_pct in &[0u32, 10, 30] {
        for &(arm, cap, coalesce) in
            &[("off", 0usize, false), ("cache", 65_536, false), ("cache+sf", 65_536, true)]
        {
            let sims: Vec<Arc<SimReplica>> = (0..REPLICAS)
                .map(|_| Arc::new(SimReplica::new(SimConfig::default())))
                .collect();
            let backends: Vec<Arc<dyn ReplicaBackend>> =
                sims.iter().map(|s| Arc::clone(s) as Arc<dyn ReplicaBackend>).collect();
            let cfg = ClusterConfig {
                policy: RoutePolicy::CacheAffinity,
                result_cache: ResultCacheConfig {
                    capacity: cap,
                    ttl_ms: 10_000,
                    coalesce,
                    ..ResultCacheConfig::default()
                },
                ..ClusterConfig::default()
            };
            let router = ClusterRouter::new(backends, cfg).expect("router");
            let mut dup_reqs = reqs.clone();
            driver::inject_duplicates(&mut dup_reqs, dup_pct as f64 / 100.0, 99);
            let t0 = Instant::now();
            let report =
                driver::closed_loop(dup_reqs, CONCURRENCY, Duration::from_secs(120), |r| {
                    router.submit(r).is_ok()
                });
            let elapsed = t0.elapsed().as_secs_f64();
            let agg = router.metrics.snapshot_over(elapsed);
            let snap = router.snapshot();
            let backend_serves: u64 = sims.iter().map(|s| s.served_total()).sum();
            ctable.row(&[
                arm.to_string(),
                dup_pct.to_string(),
                format!("{:.0} k pairs/s", agg.throughput_pairs_per_s / 1e3),
                format!("{:.2} ms", agg.overall_p99_ms),
                backend_serves.to_string(),
                snap.result_hits.to_string(),
                snap.result_coalesced.to_string(),
            ]);
            serves.insert((arm, dup_pct), backend_serves);
            speed.insert((arm, dup_pct), (agg.throughput_pairs_per_s, agg.overall_p99_ms));
            assert_eq!(report.completed + report.rejected, report.submitted);
        }
    }
    ctable.footnote("backend serves = SimReplica::serve calls actually executed");
    ctable.footnote("hits/coalesced = requests answered without touching a replica");
    ctable.print();

    for &dup_pct in &[10u32, 30] {
        let off = serves[&("off", dup_pct)];
        let cache = serves[&("cache", dup_pct)];
        let sf = serves[&("cache+sf", dup_pct)];
        let (thr_off, p99_off) = speed[&("off", dup_pct)];
        let (thr_sf, p99_sf) = speed[&("cache+sf", dup_pct)];
        println!(
            "\ndup {dup_pct}%: backend serves off={off} cache={cache} cache+sf={sf} — {}",
            if sf <= cache && cache < off {
                "result tier sheds recomputation ✓"
            } else {
                "UNEXPECTED: result tier did not reduce backend serves"
            }
        );
        println!(
            "dup {dup_pct}%: throughput {:.0}k → {:.0}k pairs/s, p99 {:.2} → {:.2} ms (off → cache+sf)",
            thr_off / 1e3,
            thr_sf / 1e3,
            p99_off,
            p99_sf
        );
        assert!(
            cache < off,
            "dup {dup_pct}%: result cache must cut backend serves ({cache} vs {off})"
        );
        assert!(
            sf <= cache,
            "dup {dup_pct}%: coalescing must not add backend serves ({sf} vs {cache})"
        );
    }
}
