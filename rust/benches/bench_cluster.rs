//! Cluster-tier bench: routing-policy ablation over N simulated
//! replicas under the paper's non-uniform candidate mix (most requests
//! small-M, a heavy tail of large-M), plus an overload phase that
//! exercises deadline-aware admission. No artifacts needed.
//!
//! Reported per policy: throughput (user-item pairs/s), p99 latency,
//! shed / SLA-miss counts, and the per-replica + aggregate feature-cache
//! hit rate. The headline effect: cache-affinity consistent hashing
//! keeps each replica's user-feature cache warm for returning users, so
//! its aggregate hit rate strictly beats round-robin's.

use std::sync::Arc;
use std::time::{Duration, Instant};

use flame::benchkit::Table;
use flame::cluster::{
    ClusterConfig, ClusterRouter, ReplicaBackend, RoutePolicy, SimConfig, SimReplica,
};
use flame::config::WorkloadConfig;
use flame::workload::{driver, Generator, Request};

const REPLICAS: usize = 3;
const USERS: u64 = 1_500;
const REQUESTS: usize = 9_000;
const CONCURRENCY: usize = 24;

fn build_router(policy: RoutePolicy, deadline_ms: u64, sim: SimConfig) -> Arc<ClusterRouter> {
    let slots = sim.slots;
    let backends: Vec<Arc<dyn ReplicaBackend>> = (0..REPLICAS)
        .map(|_| Arc::new(SimReplica::new(sim.clone())) as Arc<dyn ReplicaBackend>)
        .collect();
    let cfg = ClusterConfig {
        policy,
        deadline_ms,
        slots_per_replica: slots,
        ..ClusterConfig::default()
    };
    Arc::new(ClusterRouter::new(backends, cfg).expect("router"))
}

fn requests() -> Vec<Request> {
    let wl = WorkloadConfig {
        catalog_size: 100_000,
        zipf_theta: 0.99,
        n_users: USERS,
        // non-uniform M distribution (Table 5 style): small requests
        // dominate, large-M tail carries most of the pair volume
        candidate_mix: vec![(128, 0.55), (256, 0.25), (512, 0.15), (1024, 0.05)],
        arrival_rate: None,
        seed: 17,
    };
    Generator::new(&wl, 32).batch(REQUESTS)
}

fn main() {
    println!(
        "cluster routing-policy ablation: {REPLICAS} replicas, {USERS} users, \
         {REQUESTS} requests, non-uniform M mix [128x.55 256x.25 512x.15 1024x.05]"
    );

    let reqs = requests();
    let mut agg_hit = std::collections::HashMap::new();

    let mut table = Table::new(
        "closed-loop policy comparison",
        &[
            "policy",
            "throughput",
            "p99",
            "shed",
            "sla miss",
            "agg hit %",
            "per-replica hit %",
        ],
    );
    for policy in RoutePolicy::all() {
        let router = build_router(policy, 50, SimConfig::default());
        let t0 = Instant::now();
        let report = driver::closed_loop(reqs.clone(), CONCURRENCY, Duration::from_secs(120), |r| {
            router.submit(r).is_ok()
        });
        let elapsed = t0.elapsed().as_secs_f64();
        let snap = router.snapshot();
        let agg = router.metrics.snapshot_over(elapsed);
        let per_replica: Vec<String> = snap
            .replicas
            .iter()
            .map(|r| format!("{:.0}", r.cache_hit_rate * 100.0))
            .collect();
        table.row(&[
            policy.name().to_string(),
            format!("{:.0} k pairs/s", agg.throughput_pairs_per_s / 1e3),
            format!("{:.2} ms", agg.overall_p99_ms),
            snap.shed.to_string(),
            snap.sla_misses.to_string(),
            format!("{:.1}", snap.aggregate_cache_hit_rate * 100.0),
            per_replica.join(" / "),
        ]);
        agg_hit.insert(policy.name(), snap.aggregate_cache_hit_rate);
        assert_eq!(
            report.completed + report.rejected,
            report.submitted,
            "driver accounting"
        );
    }
    table.footnote("per-replica user-feature caches; hit rate = hits / lookups");
    table.footnote("shed = deadline admission refusals; sla miss = completed past budget");
    table.print();

    let aff = agg_hit["cache-affinity"];
    let rr = agg_hit["round-robin"];
    println!(
        "\ncache-affinity vs round-robin aggregate hit rate: {:.1}% vs {:.1}% — {}",
        aff * 100.0,
        rr * 100.0,
        if aff > rr { "affinity strictly higher ✓" } else { "UNEXPECTED: affinity not higher" }
    );

    // ---- overload phase: deadline admission under saturation ----
    // 3 replicas x 1 slot x ~2.2 ms service ≈ 1.4 k req/s capacity,
    // driven open-loop at 4 k req/s with a 6 ms budget: the router must
    // shed most of the excess at the front door.
    let overload_sim = SimConfig {
        base_us: 2_000,
        per_pair_ns: 0,
        miss_penalty_us: 200,
        slots: 1,
        ..SimConfig::default()
    };
    println!("\noverload: open-loop 4000 req/s vs ~1.4k req/s capacity, 6 ms budget");
    let mut otable = Table::new(
        "deadline admission under overload",
        &["policy", "submitted", "completed", "shed", "sla miss", "rerouted"],
    );
    for policy in RoutePolicy::all() {
        let router = build_router(policy, 6, overload_sim.clone());
        let report = driver::open_loop_cluster(
            &router,
            reqs.clone(),
            4_000.0,
            Duration::from_secs(1),
            256,
            5,
        );
        let snap = router.snapshot();
        otable.row(&[
            policy.name().to_string(),
            report.submitted.to_string(),
            report.completed.to_string(),
            snap.shed.to_string(),
            snap.sla_misses.to_string(),
            snap.rerouted.to_string(),
        ]);
    }
    otable.footnote("shed requests cost nothing downstream — the SLA-protecting trade");
    otable.print();
}
