//! Chaos bench — latency and quality-mix cost of fault storms on the
//! artifact-free sim stack. Three seeded plans (0 / 5 / 15 % store
//! fault rate, the 5 and 15 % rows adding proportional compute stalls)
//! drive the pipelined serve path under concurrent closed-loop clients;
//! each row reports p50/p99 request latency and the degradation-ladder
//! quality mix (full / stale / truncated / cached / shed counts). Every
//! run emits machine-readable `BENCH_chaos.json`.
//!
//! The headline contract this measures: a storm costs *latency and
//! freshness*, never availability — the completed count equals the
//! offered count at every fault rate. `--smoke` shrinks the request
//! count to a CI-sized run that still gates on that invariant plus a
//! non-empty degraded-quality mix at 15 %.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use flame::benchkit::Table;
use flame::chaos::{FaultPlan, ServeQuality, QUALITY_RUNGS};
use flame::config::{CacheMode, ModelConfig, StackConfig};
use flame::dso::{ComputeBackend, SimEngine};
use flame::netsim::{Link, LinkConfig};
use flame::server::pipeline::StackBuilder;
use flame::server::ServingStack;
use flame::util::json::Json;
use flame::workload::Request;

const OUT_PATH: &str = "BENCH_chaos.json";
const SEQ: usize = 16;
const D: usize = 8;
const TASKS: usize = 3;
const PROFILES: [usize; 2] = [8, 16];
const CLIENTS: usize = 8;
const SEED: u64 = 42;

/// (label, fault rate in percent). The spec is derived from the rate so
/// a storm reproduces from `(rate, SEED)` alone.
const RATES: [(&str, u32); 3] = [("0%", 0), ("5%", 5), ("15%", 15)];

fn spec_for(rate_pct: u32) -> String {
    if rate_pct == 0 {
        return String::new();
    }
    let p = rate_pct as f64 / 100.0;
    // store timeouts carry the storm; delays and stalls ride at a third
    // of the rate each so the plan exercises more than one fault class
    format!(
        "store_timeout:p={p},store_delay:p={:.4},us=150,stall:p={:.4},us=200",
        p / 3.0,
        p / 3.0
    )
}

fn model_cfg() -> ModelConfig {
    ModelConfig {
        name: "sim".into(),
        seq_len: SEQ,
        n_blocks: 1,
        layers_per_block: 1,
        d_model: D,
        n_heads: 1,
        n_tasks: TASKS,
        m_profiles: PROFILES.to_vec(),
        native_m: PROFILES[PROFILES.len() - 1],
    }
}

fn sim_stack() -> Arc<ServingStack> {
    let mut cfg = StackConfig::default();
    cfg.pda.cache_mode = CacheMode::Sync;
    cfg.pda.numa_binding = false;
    cfg.server.pipeline = true;
    cfg.server.feature_workers = 2;
    cfg.server.pipeline_workers = 2;
    let link = Arc::new(Link::new(LinkConfig {
        rtt: Duration::from_micros(200),
        bandwidth_bps: 1e9,
        jitter: 0.0,
        fail_rate: 0.0,
    }));
    let backends: Vec<Arc<dyn ComputeBackend>> = PROFILES
        .iter()
        .map(|&m| {
            Arc::new(SimEngine::new(m, SEQ, D, TASKS).with_delay(Duration::from_micros(150)))
                as Arc<dyn ComputeBackend>
        })
        .collect();
    Arc::new(
        StackBuilder::new("sim", "sim", cfg)
            .with_link(link)
            .build_from_backends(model_cfg(), SEED, backends)
            .expect("sim stack"),
    )
}

fn request(id: u64, m: usize) -> Request {
    Request {
        request_id: id,
        user_id: id % 512,
        history: (0..8u64).map(|i| id.wrapping_mul(31) ^ i).collect(),
        // cold candidate ids: every request exercises the remote store,
        // so the fault rate is felt at full strength
        candidates: (0..m as u64).map(|i| id.wrapping_mul(1_009) + i).collect(),
        ..Default::default()
    }
}

fn percentile(sorted_us: &[u64], p: f64) -> u64 {
    if sorted_us.is_empty() {
        return 0;
    }
    let idx = ((sorted_us.len() as f64 - 1.0) * p).round() as usize;
    sorted_us[idx.min(sorted_us.len() - 1)]
}

struct RateResult {
    label: &'static str,
    rate_pct: u32,
    spec: String,
    offered: u64,
    completed: u64,
    p50_us: u64,
    p99_us: u64,
    quality: [u64; QUALITY_RUNGS],
    injected_total: u64,
}

fn run_rate(label: &'static str, rate_pct: u32, n_requests: u64) -> RateResult {
    let stack = sim_stack();
    let spec = spec_for(rate_pct);
    let plan = Arc::new(FaultPlan::parse(&spec, SEED).expect("bench plan"));
    if rate_pct > 0 {
        stack.arm_chaos(Arc::clone(&plan));
    }
    let handle = stack.spawn_pipeline();

    let latencies: Mutex<Vec<u64>> = Mutex::new(Vec::with_capacity(n_requests as usize));
    let next = std::sync::atomic::AtomicU64::new(0);
    let completed = std::sync::atomic::AtomicU64::new(0);
    std::thread::scope(|s| {
        for _ in 0..CLIENTS {
            let handle = &handle;
            let latencies = &latencies;
            let next = &next;
            let completed = &completed;
            s.spawn(move || loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= n_requests {
                    return;
                }
                let m = [3usize, 6, 11, 16][(i % 4) as usize];
                let t0 = Instant::now();
                handle
                    .serve(&request(i, m))
                    .expect("a fault storm must cost latency, never availability");
                let us = t0.elapsed().as_micros() as u64;
                completed.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                latencies.lock().unwrap_or_else(|e| e.into_inner()).push(us);
            });
        }
    });
    handle.shutdown();

    let mut sorted = latencies.into_inner().unwrap_or_else(|e| e.into_inner());
    sorted.sort_unstable();
    let inj = plan.injected();
    RateResult {
        label,
        rate_pct,
        spec,
        offered: n_requests,
        completed: completed.load(std::sync::atomic::Ordering::Relaxed),
        p50_us: percentile(&sorted, 0.50),
        p99_us: percentile(&sorted, 0.99),
        quality: stack.metrics.quality_counts(),
        injected_total: inj.store_delays
            + inj.store_errors
            + inj.store_timeouts
            + inj.compute_stalls,
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let n_requests: u64 = if smoke { 240 } else { 2_000 };
    println!(
        "chaos storm cost: {n_requests} requests x {} fault rates, {CLIENTS} clients, seed {SEED}",
        RATES.len()
    );

    let mut table = Table::new(
        "fault-rate ladder (pipelined sim stack)",
        &["fault rate", "completed", "p50", "p99", "full", "stale", "trunc", "injected"],
    );
    let mut rows: Vec<RateResult> = Vec::new();
    for (label, rate) in RATES {
        let r = run_rate(label, rate, n_requests);
        assert_eq!(
            r.completed, r.offered,
            "{label}: the no-lost-request invariant must hold under the storm"
        );
        table.row(&[
            r.label.to_string(),
            format!("{}/{}", r.completed, r.offered),
            format!("{:.2} ms", r.p50_us as f64 / 1_000.0),
            format!("{:.2} ms", r.p99_us as f64 / 1_000.0),
            r.quality[ServeQuality::Full.index()].to_string(),
            r.quality[ServeQuality::StaleFeatures.index()].to_string(),
            r.quality[ServeQuality::TruncatedCandidates.index()].to_string(),
            r.injected_total.to_string(),
        ]);
        rows.push(r);
    }
    table.footnote("quality mix counts responses per degradation-ladder rung");
    table.print();

    // CI gate: the storm actually degraded something at 15%
    let worst = rows.last().expect("rates ran");
    assert!(
        worst.quality[ServeQuality::StaleFeatures.index()] >= 1,
        "15% storm produced no stale-feature responses — injection plane dead?"
    );

    let mut rates_json = BTreeMap::new();
    for r in &rows {
        let mut o = BTreeMap::new();
        o.insert("rate_pct".into(), Json::Num(r.rate_pct as f64));
        o.insert("spec".into(), Json::Str(r.spec.clone()));
        o.insert("offered".into(), Json::Num(r.offered as f64));
        o.insert("completed".into(), Json::Num(r.completed as f64));
        o.insert("p50_us".into(), Json::Num(r.p50_us as f64));
        o.insert("p99_us".into(), Json::Num(r.p99_us as f64));
        o.insert("injected_faults".into(), Json::Num(r.injected_total as f64));
        let mut q = BTreeMap::new();
        for i in 0..QUALITY_RUNGS {
            let rung = ServeQuality::from_index(i).expect("rung index");
            q.insert(rung.as_str().to_string(), Json::Num(r.quality[i] as f64));
        }
        o.insert("quality".into(), Json::Obj(q));
        rates_json.insert(r.label.to_string(), Json::Obj(o));
    }
    let mut top = BTreeMap::new();
    top.insert("bench".into(), Json::Str("chaos".into()));
    top.insert("backend".into(), Json::Str("sim".into()));
    top.insert("smoke".into(), Json::Bool(smoke));
    top.insert("seed".into(), Json::Num(SEED as f64));
    top.insert("requests_per_rate".into(), Json::Num(n_requests as f64));
    top.insert("clients".into(), Json::Num(CLIENTS as f64));
    top.insert("rates".into(), Json::Obj(rates_json));
    match std::fs::write(OUT_PATH, Json::Obj(top).to_string()) {
        Ok(()) => eprintln!("  wrote {OUT_PATH}"),
        Err(e) => eprintln!("  could not write {OUT_PATH}: {e}"),
    }
}
