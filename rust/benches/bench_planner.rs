//! Micro-bench: DSO split planner + request-queue + staging arena hot-path
//! costs. These sit on the per-request critical path, so they must be
//! negligible against model compute (§Perf L3 target). No artifacts.

use flame::benchkit::Bencher;
use flame::batching::RequestQueue;
use flame::dso::plan_split;
use flame::pda::StagingArena;
use flame::util::rng::Rng;

fn main() {
    let mut b = Bencher::from_env();
    let profiles = [128usize, 256, 512, 1024];
    let mut rng = Rng::new(9);

    b.bench("planner/plan_split_mixed", || {
        let m = 1 + rng.below(2048) as usize;
        std::hint::black_box(plan_split(m, &profiles));
    });

    b.bench("planner/plan_split_exact", || {
        std::hint::black_box(plan_split(512, &profiles));
    });

    let queue = RequestQueue::new(4096);
    b.bench("queue/push_pop", || {
        queue.push(42u64).unwrap();
        std::hint::black_box(queue.pop());
    });

    let mut arena = StagingArena::new(1 << 20);
    let row = vec![0.5f32; 128];
    b.bench("staging/reset_and_stage_1k_rows", || {
        arena.reset();
        for _ in 0..1024 {
            std::hint::black_box(arena.stage(&row));
        }
    });

    // the baseline arm's equivalent: fresh Vec per request
    b.bench("staging/alloc_vec_1k_rows_baseline", || {
        let mut bufs = Vec::with_capacity(1024);
        for _ in 0..1024 {
            let mut v = vec![0.0f32; 128];
            v.copy_from_slice(&row);
            bufs.push(v);
        }
        std::hint::black_box(bufs);
    });
}
