//! Cancellation bench — goodput recovered by end-to-end deadline
//! propagation when a flash crowd overruns capacity. One seeded crowd
//! (tight budgets, ~Nx a single serial compute worker) followed by a
//! cohort of follow-ups replays against two arms on the artifact-free
//! `SimEngine` pipeline: cancellation off (every admitted request runs
//! to completion) and on (doomed work is purged at the earliest stage
//! boundary). At 1x load the arms must be indistinguishable — the
//! cancel plane is pure overhead there and must not fire; at 2x the
//! cancel arm converts burned compute into follow-up goodput.
//!
//! Every run emits machine-readable `BENCH_cancel.json`. `--smoke`
//! shrinks the crowd to a CI-sized run that still gates on the 2x
//! cancel arm beating no-cancel on goodput with a non-empty ledger.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use flame::benchkit::Table;
use flame::config::{CacheMode, ModelConfig, StackConfig};
use flame::dso::{ComputeBackend, SimEngine};
use flame::server::pipeline::StackBuilder;
use flame::server::ServingStack;
use flame::util::json::Json;
use flame::workload::Request;

const OUT_PATH: &str = "BENCH_cancel.json";
const SEED: u64 = 53;
const SEQ: usize = 16;
const D: usize = 8;
const TASKS: usize = 3;
const PROFILES: [usize; 2] = [4, 8];
/// Per-launch compute time on the single serial m=4 executor.
const COMPUTE: Duration = Duration::from_millis(3);

fn sim_stack(cancel: bool) -> Arc<ServingStack> {
    let model_cfg = ModelConfig {
        name: "sim".into(),
        seq_len: SEQ,
        n_blocks: 1,
        layers_per_block: 1,
        d_model: D,
        n_heads: 1,
        n_tasks: TASKS,
        m_profiles: PROFILES.to_vec(),
        native_m: PROFILES[PROFILES.len() - 1],
    };
    let mut cfg = StackConfig::default();
    cfg.pda.cache_mode = CacheMode::Sync;
    cfg.pda.numa_binding = false;
    cfg.server.pipeline = true;
    cfg.server.cancel = cancel;
    cfg.server.feature_workers = 1;
    cfg.server.pipeline_workers = 1;
    cfg.server.handoff_capacity = 4;
    cfg.dso.queue_capacity = 256; // admit every burst — no shedding noise
    let backends: Vec<Arc<dyn ComputeBackend>> = PROFILES
        .iter()
        .map(|&m| {
            Arc::new(SimEngine::new(m, SEQ, D, TASKS).with_delay(COMPUTE))
                as Arc<dyn ComputeBackend>
        })
        .collect();
    Arc::new(
        StackBuilder::new("sim", "sim", cfg)
            .build_from_backends(model_cfg, SEED, backends)
            .expect("sim stack"),
    )
}

fn request(id: u64) -> Request {
    Request {
        request_id: id,
        user_id: id % 7,
        history: (0..8u64).map(|i| id.wrapping_mul(31) ^ i).collect(),
        candidates: (0..4u64).map(|i| id.wrapping_mul(17) ^ (i << 8)).collect(),
        ..Default::default()
    }
}

struct Load {
    label: &'static str,
    crowd: u64,
    crowd_budget: Duration,
    follow: u64,
    follow_budget: Duration,
}

struct ArmResult {
    cancel: bool,
    load: &'static str,
    submitted: u64,
    goodput: u64,
    cancelled: u64,
    saved_pairs: u64,
    other_errs: u64,
    wall_ms: f64,
}

/// Replay one load shape against a fresh stack: the crowd, then the
/// follow-ups, all on the pipeline submit path with explicit budgets.
/// Goodput counts a response that arrived inside its own budget.
fn run_arm(cancel: bool, load: &Load) -> ArmResult {
    let stack = sim_stack(cancel);
    let handle = stack.spawn_pipeline();
    let t0 = Instant::now();
    let mut pending = Vec::new();
    for i in 0..load.crowd {
        let rx = handle
            .submit_with_deadline(request(i), load.crowd_budget)
            .expect("crowd admitted — queue sized for it");
        pending.push((rx, load.crowd_budget));
    }
    for i in 0..load.follow {
        let rx = handle
            .submit_with_deadline(request(load.crowd + i), load.follow_budget)
            .expect("follow-up admitted");
        pending.push((rx, load.follow_budget));
    }
    let (mut goodput, mut other_errs) = (0u64, 0u64);
    for (rx, budget) in pending {
        match rx.recv().expect("pipeline alive: every request must resolve") {
            Ok(resp) => {
                if Duration::from_micros(resp.overall_us) <= budget {
                    goodput += 1;
                }
            }
            Err(flame::Error::Cancelled(..)) => {}
            Err(_) => other_errs += 1,
        }
    }
    let wall_ms = t0.elapsed().as_secs_f64() * 1_000.0;
    let result = ArmResult {
        cancel,
        load: load.label,
        submitted: load.crowd + load.follow,
        goodput,
        cancelled: stack.metrics.cancelled_total(),
        saved_pairs: stack.metrics.cancelled_saved_pairs(),
        other_errs,
        wall_ms,
    };
    handle.shutdown();
    result
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let loads = if smoke {
        [
            Load {
                label: "1x",
                crowd: 8,
                crowd_budget: Duration::from_millis(400),
                follow: 8,
                follow_budget: Duration::from_millis(400),
            },
            Load {
                label: "2x",
                crowd: 24,
                crowd_budget: Duration::from_millis(15),
                follow: 8,
                follow_budget: Duration::from_millis(60),
            },
        ]
    } else {
        [
            Load {
                label: "1x",
                crowd: 16,
                crowd_budget: Duration::from_millis(500),
                follow: 16,
                follow_budget: Duration::from_millis(500),
            },
            Load {
                label: "2x",
                crowd: 48,
                crowd_budget: Duration::from_millis(20),
                follow: 16,
                follow_budget: Duration::from_millis(100),
            },
        ]
    };
    println!(
        "cancellation goodput: serial sim pipeline ({} ms/launch), crowd + follow-ups, \
         cancel off vs on, seed {SEED}{}",
        COMPUTE.as_millis(),
        if smoke { " [smoke]" } else { "" }
    );

    let mut arms = Vec::new();
    for load in &loads {
        arms.push(run_arm(false, load));
        arms.push(run_arm(true, load));
    }

    let mut table = Table::new(
        "goodput under a flash crowd (identical load, cancellation off vs on)",
        &["arm", "load", "submitted", "goodput", "cancelled", "saved pairs", "wall ms"],
    );
    for a in &arms {
        table.row(&[
            if a.cancel { "on" } else { "off" }.to_string(),
            a.load.to_string(),
            a.submitted.to_string(),
            a.goodput.to_string(),
            a.cancelled.to_string(),
            a.saved_pairs.to_string(),
            format!("{:.1}", a.wall_ms),
        ]);
    }
    table.footnote("goodput = responses inside their own deadline budget");
    table.print();

    // CI gates. 2x: cancellation must convert doomed work into
    // follow-up goodput with a non-empty, compute-saving ledger.
    let off_2x = arms.iter().find(|a| !a.cancel && a.load == "2x").expect("off/2x arm");
    let on_2x = arms.iter().find(|a| a.cancel && a.load == "2x").expect("on/2x arm");
    assert!(
        on_2x.goodput > off_2x.goodput,
        "cancel arm must beat no-cancel on goodput at 2x: {} vs {}",
        on_2x.goodput,
        off_2x.goodput
    );
    assert!(on_2x.cancelled > 0, "2x cancel arm never dropped doomed work");
    assert!(on_2x.saved_pairs > 0, "dropped work must report saved compute");
    assert_eq!(off_2x.cancelled, 0, "cancel-off arm must never cancel");
    for a in &arms {
        assert_eq!(a.other_errs, 0, "non-cancel errors on arm {}/{}", a.cancel, a.load);
    }

    let mut arms_json = BTreeMap::new();
    for a in &arms {
        let mut o = BTreeMap::new();
        o.insert("submitted".into(), Json::Num(a.submitted as f64));
        o.insert("goodput".into(), Json::Num(a.goodput as f64));
        o.insert("cancelled".into(), Json::Num(a.cancelled as f64));
        o.insert("saved_pairs".into(), Json::Num(a.saved_pairs as f64));
        o.insert("wall_ms".into(), Json::Num(a.wall_ms));
        arms_json.insert(
            format!("{}_{}", if a.cancel { "cancel" } else { "no_cancel" }, a.load),
            Json::Obj(o),
        );
    }
    let mut top = BTreeMap::new();
    top.insert("bench".into(), Json::Str("cancel".into()));
    top.insert("backend".into(), Json::Str("sim-pipeline".into()));
    top.insert("smoke".into(), Json::Bool(smoke));
    top.insert("seed".into(), Json::Num(SEED as f64));
    top.insert("compute_us".into(), Json::Num(COMPUTE.as_micros() as f64));
    top.insert("arms".into(), Json::Obj(arms_json));
    match std::fs::write(OUT_PATH, Json::Obj(top).to_string()) {
        Ok(()) => eprintln!("  wrote {OUT_PATH}"),
        Err(e) => eprintln!("  could not write {OUT_PATH}: {e}"),
    }
}
