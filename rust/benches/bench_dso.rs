//! Table 5 — DSO ablation under simulated mixed-traffic workloads, per
//! candidate-count distribution:
//!
//! * arms: Default (Implicit Shape / pad-to-max), DSO split (Explicit
//!   Shape / descending batch split), and DSO split+coalesce
//!   (cross-request remainder packing);
//! * m-mixes: `uniform | bimodal | zipf` over the profile support
//!   (including off-profile M values — the paper's skewed upstream).
//!
//! On a segment-native backend the coalesced arm executes strictly
//! fewer padded rows (`waste_fraction`) than the per-request split arm
//! on the skewed mixes, with per-request added latency bounded by
//! `coalesce_wait_us` (asserted artifact-free in `tests/dso_integration`
//! over `SimEngine`). The PJRT engine *emulates* mixed-history batches
//! by replaying the launch per segment, and the waste accounting
//! honestly includes that replay cost — so on real artifacts the
//! coalesce arm's gains await the natively segmented profiles tracked
//! in ROADMAP.md.
//!
//! Default scenario: `bench` (profiles {16,32,64,128}); run with
//! `--scenario long` after `make artifacts-full` for the paper's
//! {128,256,512,1024} @ L=1024.

use std::sync::Arc;
use std::time::Duration;

use flame::benchkit::{table, BenchArgs, Table};
use flame::config::{CacheMode, DsoMode, StackConfig, WorkloadConfig};
use flame::manifest::Manifest;
use flame::runtime::Runtime;
use flame::server::pipeline::StackBuilder;
use flame::workload::{Generator, MDist};

struct Row {
    label: String,
    tput: f64,
    mean_ms: f64,
    p99_ms: f64,
    waste: f64,
    coalesced_rows: u64,
}

fn main() {
    let args = BenchArgs::from_env();
    let scenario = args.scenario.clone().unwrap_or_else(|| "bench".to_string());
    let seconds = args.measure_time.as_secs_f64().max(3.0);
    let workers = 4;
    const COALESCE_WAIT_US: u64 = 200;

    let manifest = match Manifest::load("artifacts") {
        Ok(m) if m.scenarios.contains_key(&scenario) => m,
        _ => {
            eprintln!(
                "bench_dso: artifacts for '{scenario}' missing — run `make artifacts`; skipping"
            );
            return;
        }
    };

    println!(
        "\nDSO ablation — scenario '{scenario}', {seconds:.0}s per arm, \
         coalesce wait {COALESCE_WAIT_US}µs"
    );
    let mut rows: Vec<Row> = Vec::new();
    for (dist_name, dist) in [
        ("uniform", MDist::Uniform),
        ("bimodal", MDist::Bimodal),
        ("zipf", MDist::Zipf),
    ] {
        for (arm, mode, coalesce) in [
            ("Default (Implicit Shape)", DsoMode::ImplicitPad, false),
            ("DSO split", DsoMode::Explicit, false),
            ("DSO split+coalesce", DsoMode::Explicit, true),
        ] {
            let label = format!("{arm} @ {dist_name}");
            if !args.wants(&label) {
                continue;
            }
            let rt = Runtime::new().expect("pjrt");
            let mut cfg = StackConfig::default();
            cfg.pda.cache_mode = CacheMode::Async; // feature path constant
            cfg.dso.mode = mode;
            cfg.dso.coalesce = coalesce;
            cfg.dso.coalesce_wait_us = COALESCE_WAIT_US;
            cfg.server.pipeline_workers = workers;

            eprintln!("  [{label}] building stack ...");
            let stack = Arc::new(
                StackBuilder::new(&scenario, "fused", cfg.clone())
                    .build(&rt, &manifest)
                    .expect("stack"),
            );
            let profiles = stack.orchestrator.profiles().to_vec();
            let wl = WorkloadConfig {
                catalog_size: 100_000,
                zipf_theta: 1.0,
                n_users: 10_000,
                candidate_mix: dist.mix(&profiles),
                arrival_rate: None,
                seed: 55,
            };
            let mut gen = Generator::new(&wl, stack.model_cfg.seq_len);
            let requests = gen.batch(100_000);

            stack.drive_closed_loop(&requests[..32], workers, Duration::from_secs(60));
            stack.query.drain_refreshes();
            stack.metrics.overall.reset();
            let pairs0 = stack.metrics.pairs();

            let t0 = std::time::Instant::now();
            stack.drive_closed_loop(&requests[32..], workers, Duration::from_secs_f64(seconds));
            let elapsed = t0.elapsed().as_secs_f64();

            let pairs = (stack.metrics.pairs() - pairs0) as f64;
            let snap = stack.metrics.snapshot_over(elapsed);
            let cs = stack.orchestrator.coalesce_stats();
            eprintln!(
                "  [{label}] {:.1}k pairs/s, {:.2} ms mean, waste {:.0}%, coalesced rows {}",
                pairs / elapsed / 1e3,
                snap.overall_mean_ms,
                stack.orchestrator.waste_fraction() * 100.0,
                cs.coalesced_rows
            );
            rows.push(Row {
                label,
                tput: pairs / elapsed,
                mean_ms: snap.overall_mean_ms,
                p99_ms: snap.overall_p99_ms,
                waste: stack.orchestrator.waste_fraction(),
                coalesced_rows: cs.coalesced_rows,
            });
        }
    }

    let mut t = Table::new(
        &format!("Table 5 (reproduced) — DSO ablation x m-dist, scenario '{scenario}'"),
        &[
            "Ablation Study",
            "Throughput",
            "Overall Latency",
            "P99 Latency",
            "Padded Rows",
            "Coalesced Rows",
        ],
    );
    for r in &rows {
        t.row(&[
            r.label.clone(),
            table::kthroughput(r.tput),
            table::ms(r.mean_ms),
            table::ms(r.p99_ms),
            format!("{:.0} %", r.waste * 100.0),
            r.coalesced_rows.to_string(),
        ]);
    }
    let find = |needle: &str| rows.iter().find(|r| r.label == needle);
    if let (Some(imp), Some(dso)) =
        (find("Default (Implicit Shape) @ uniform"), find("DSO split @ uniform"))
    {
        t.footnote(&format!(
            "DSO vs default @ uniform: {} throughput, {} latency (paper's Table 5, \
             profiles-only mix: 1.3x / 2.3x; this uniform arm also draws off-profile M)",
            table::ratio(dso.tput, imp.tput),
            table::ratio(imp.mean_ms, dso.mean_ms),
        ));
    }
    for dist in ["bimodal", "zipf"] {
        if let (Some(split), Some(co)) = (
            find(&format!("DSO split @ {dist}")),
            find(&format!("DSO split+coalesce @ {dist}")),
        ) {
            t.footnote(&format!(
                "coalesce @ {dist}: waste {:.1}% -> {:.1}% (strictly lower on \
                 segment-native backends; PJRT emulation replays per history, and \
                 its replay cost is included), added latency bounded by {}µs",
                split.waste * 100.0,
                co.waste * 100.0,
                COALESCE_WAIT_US,
            ));
        }
    }
    t.footnote("throughput in thousands of user-item pairs/s");
    t.print();
}
