//! Table 5 — DSO ablation under simulated mixed-traffic workloads:
//! Default (Implicit Shape / pad-to-max) vs DSO (Explicit Shape /
//! descending batch split), candidate counts uniform over the scenario's
//! profiles.
//!
//! Default scenario: `bench` (M uniform over {16,32,64,128}); run with
//! `--scenario long` after `make artifacts-full` for the paper's
//! {128,256,512,1024} @ L=1024.

use std::sync::Arc;
use std::time::Duration;

use flame::benchkit::{table, BenchArgs, Table};
use flame::config::{CacheMode, DsoMode, StackConfig, WorkloadConfig};
use flame::manifest::Manifest;
use flame::runtime::Runtime;
use flame::server::pipeline::StackBuilder;
use flame::workload::Generator;

fn main() {
    let args = BenchArgs::from_env();
    let scenario = args.scenario.clone().unwrap_or_else(|| "bench".to_string());
    let seconds = (args.measure_time.as_secs_f64() * 2.0).max(6.0);
    let workers = 4;

    let manifest = match Manifest::load("artifacts") {
        Ok(m) if m.scenarios.contains_key(&scenario) => m,
        _ => {
            eprintln!("bench_dso: artifacts for '{scenario}' missing — run `make artifacts`; skipping");
            return;
        }
    };

    println!("\nDSO ablation — scenario '{scenario}', mixed M uniform over profiles, {seconds:.0}s per arm");
    let mut rows = Vec::new();
    for (label, mode) in [
        ("Default (Implicit Shape)", DsoMode::ImplicitPad),
        ("DSO (Explicit Shape)", DsoMode::Explicit),
    ] {
        if !args.wants(label) {
            continue;
        }
        let rt = Runtime::new().expect("pjrt");
        let mut cfg = StackConfig::default();
        cfg.pda.cache_mode = CacheMode::Async; // feature path constant
        cfg.dso.mode = mode;
        cfg.server.pipeline_workers = workers;

        eprintln!("  [{label}] building stack ...");
        let stack = Arc::new(
            StackBuilder::new(&scenario, "fused", cfg.clone())
                .build(&rt, &manifest)
                .expect("stack"),
        );
        let profiles = stack.orchestrator.profiles().to_vec();
        let wl = WorkloadConfig {
            catalog_size: 100_000,
            zipf_theta: 1.0,
            n_users: 10_000,
            candidate_mix: WorkloadConfig::uniform_mix(&profiles),
            arrival_rate: None,
            seed: 55,
        };
        let mut gen = Generator::new(&wl, stack.model_cfg.seq_len);
        let requests = gen.batch(100_000);

        stack.drive_closed_loop(&requests[..32], workers, Duration::from_secs(60));
        stack.query.drain_refreshes();
        stack.metrics.overall.reset();
        let pairs0 = stack.metrics.pairs();

        let t0 = std::time::Instant::now();
        stack.drive_closed_loop(&requests[32..], workers, Duration::from_secs_f64(seconds));
        let elapsed = t0.elapsed().as_secs_f64();

        let pairs = (stack.metrics.pairs() - pairs0) as f64;
        let snap = stack.metrics.snapshot_over(elapsed);
        rows.push((
            label,
            pairs / elapsed,
            snap.overall_mean_ms,
            snap.overall_p99_ms,
            stack.orchestrator.waste_fraction(),
        ));
        eprintln!(
            "  [{label}] {:.1}k pairs/s, {:.2} ms mean, waste {:.0}%",
            pairs / elapsed / 1e3,
            snap.overall_mean_ms,
            stack.orchestrator.waste_fraction() * 100.0
        );
    }

    let mut t = Table::new(
        &format!("Table 5 (reproduced) — DSO ablation under mixed traffic, scenario '{scenario}'"),
        &["Ablation Study", "Throughput", "Overall Latency", "P99 Latency", "Padded Rows"],
    );
    for (label, tput, mean, p99, waste) in &rows {
        t.row(&[
            label.to_string(),
            table::kthroughput(*tput),
            table::ms(*mean),
            table::ms(*p99),
            format!("{:.0} %", waste * 100.0),
        ]);
    }
    if rows.len() == 2 {
        t.footnote(&format!(
            "DSO vs default: {} throughput, {} latency (paper: 1.3x / 2.3x)",
            table::ratio(rows[1].1, rows[0].1),
            table::ratio(rows[0].2, rows[1].2),
        ));
    }
    t.footnote("throughput in thousands of user-item pairs/s");
    t.print();
}
