//! Micro-bench: PJRT runtime hot-path costs on the tiny engine —
//! host→device upload, execute with device-resident weights, and the
//! end-to-end `Engine::run`. Quantifies what device-resident weights buy
//! (the TensorRT-weights-in-GPU-memory analogue) by comparing against a
//! per-call weight re-upload.

use std::sync::Arc;

use flame::benchkit::Bencher;
use flame::manifest::Manifest;
use flame::runtime::{EngineKey, Runtime};

fn main() {
    let mut b = Bencher::from_env();
    let manifest = match Manifest::load("artifacts") {
        Ok(m) if m.scenarios.contains_key("tiny") => m,
        _ => {
            eprintln!("bench_runtime: artifacts missing — run `make artifacts`; skipping");
            return;
        }
    };
    let rt = Runtime::new().expect("pjrt");
    let weights = rt.upload_weights(&manifest, "tiny").expect("weights");
    let engine = rt
        .load_engine_with_weights(&manifest, &EngineKey::new("tiny", "fused", 8), Arc::clone(&weights))
        .expect("engine");

    let hist = vec![0.1f32; engine.hist_len()];
    let cands = vec![0.05f32; engine.cands_len()];

    b.bench("runtime/engine_run_tiny_fused_m8", || {
        std::hint::black_box(engine.run(&hist, &cands).expect("run"));
    });

    // what re-uploading weights every call would cost (the naive design
    // this runtime avoids)
    let tensors = manifest.load_weights("tiny").expect("load");
    b.bench("runtime/weights_reupload_per_call", || {
        let bufs = rt.upload_weights(&manifest, "tiny").expect("upload");
        std::hint::black_box(bufs.total_bytes);
    });
    println!(
        "\nweight set: {} tensors, {:.2} MB (uploaded once per scenario, shared across engines)",
        tensors.len(),
        weights.total_bytes as f64 / 1e6
    );

    // compile cost (the implicit-shape mode's hidden stall if shapes
    // were compiled on demand)
    b.args.min_iters = 3;
    b.args.measure_time = std::time::Duration::from_secs(1);
    b.bench("runtime/compile_tiny_engine", || {
        let e = rt
            .load_engine_with_weights(
                &manifest,
                &EngineKey::new("tiny", "api", 8),
                Arc::clone(&weights),
            )
            .expect("engine");
        std::hint::black_box(e.flops);
    });
}
