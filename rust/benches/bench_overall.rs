//! Fig 13 — overall staged comparison: the three modules' traffic
//! scenarios side by side, each arm measured on the full stack.
//!
//! * PDA stage  : fixed-M Zipf traffic, baseline vs full PDA
//! * FKE stage  : pure compute, naive vs fused engines
//! * DSO stage  : mixed-M traffic, implicit vs explicit shape

use std::sync::Arc;
use std::time::Duration;

use flame::benchkit::{table, BenchArgs, Table};
use flame::config::{CacheMode, DsoMode, PdaConfig, StackConfig, WorkloadConfig};
use flame::manifest::Manifest;
use flame::runtime::{EngineKey, Runtime};
use flame::server::pipeline::StackBuilder;
use flame::workload::Generator;

/// Drive the full stack and return (pairs/s, mean ms).
fn drive_stack(
    manifest: &Manifest,
    scenario: &str,
    cfg: StackConfig,
    mix: Vec<(usize, f64)>,
    seconds: f64,
) -> (f64, f64) {
    let workers = cfg.server.pipeline_workers;
    let rt = Runtime::new().expect("pjrt");
    let stack = Arc::new(
        StackBuilder::new(scenario, "fused", cfg).build(&rt, manifest).expect("stack"),
    );
    let wl = WorkloadConfig {
        catalog_size: 100_000,
        zipf_theta: 1.0,
        n_users: 10_000,
        candidate_mix: mix,
        arrival_rate: None,
        seed: 33,
    };
    let mut gen = Generator::new(&wl, stack.model_cfg.seq_len);
    let requests = gen.batch(100_000);
    stack.drive_closed_loop(&requests[..32], workers, Duration::from_secs(60));
    stack.query.drain_refreshes();
    stack.metrics.overall.reset();
    let pairs0 = stack.metrics.pairs();
    let t0 = std::time::Instant::now();
    stack.drive_closed_loop(&requests[32..], workers, Duration::from_secs_f64(seconds));
    let elapsed = t0.elapsed().as_secs_f64();
    let snap = stack.metrics.snapshot_over(elapsed);
    (((stack.metrics.pairs() - pairs0) as f64) / elapsed, snap.overall_mean_ms)
}

fn main() {
    let args = BenchArgs::from_env();
    let scenario = args.scenario.clone().unwrap_or_else(|| "bench".to_string());
    let seconds = (args.measure_time.as_secs_f64()).max(4.0);

    let manifest = match Manifest::load("artifacts") {
        Ok(m) if m.scenarios.contains_key(&scenario) => m,
        _ => {
            eprintln!("bench_overall: artifacts missing — run `make artifacts`; skipping");
            return;
        }
    };
    let model_cfg = manifest.scenario(&scenario).unwrap().config.clone();
    let native = model_cfg.native_m;

    let mut t = Table::new(
        &format!("Fig 13 (reproduced) — overall staged comparison, scenario '{scenario}'"),
        &["Traffic scenario", "Arm", "Throughput", "Mean Latency", "Gain"],
    );

    // ---- PDA stage ----
    if args.wants("pda") {
        eprintln!("[overall] PDA stage ...");
        // low CPU utilization like the paper's Table 3 methodology, so
        // feature latency is exposed rather than overlapped (see
        // bench_pda.rs for the full rationale)
        let pda_workers = (flame::pda::numa::num_cpus() / 2).max(1);
        let base_cfg = {
            let mut c = StackConfig::default();
            c.pda = PdaConfig::baseline();
            c.server.pipeline_workers = pda_workers;
            c
        };
        let full_cfg = {
            let mut c = StackConfig::default();
            c.server.pipeline_workers = pda_workers;
            c
        };
        let (t_base, l_base) =
            drive_stack(&manifest, &scenario, base_cfg, vec![(native, 1.0)], seconds);
        let (t_full, l_full) =
            drive_stack(&manifest, &scenario, full_cfg, vec![(native, 1.0)], seconds);
        t.row(&[
            "PDA (bypass, fixed M)".into(),
            "baseline".into(),
            table::kthroughput(t_base),
            table::ms(l_base),
            String::new(),
        ]);
        t.row(&[
            String::new(),
            "full PDA".into(),
            table::kthroughput(t_full),
            table::ms(l_full),
            format!("{} tput, {} lat", table::ratio(t_full, t_base), table::ratio(l_base, l_full)),
        ]);
    }

    // ---- FKE stage (pure compute, naive vs fused) ----
    if args.wants("fke") {
        eprintln!("[overall] FKE stage ...");
        let rt = Runtime::new().expect("pjrt");
        let weights = rt.upload_weights(&manifest, &scenario).expect("weights");
        let mut fke_rows = Vec::new();
        for variant in ["naive", "fused"] {
            if manifest.find(&scenario, variant, native).is_err() {
                continue;
            }
            let engine = rt
                .load_engine_with_weights(
                    &manifest,
                    &EngineKey::new(&scenario, variant, native),
                    Arc::clone(&weights),
                )
                .expect("engine");
            let hist = vec![0.1f32; engine.hist_len()];
            let cands = vec![0.05f32; engine.cands_len()];
            // quick timed loop
            for _ in 0..3 {
                let _ = engine.run(&hist, &cands);
            }
            let t0 = std::time::Instant::now();
            let mut iters = 0;
            while t0.elapsed().as_secs_f64() < seconds / 2.0 {
                let _ = engine.run(&hist, &cands).expect("run");
                iters += 1;
            }
            let mean_ms = t0.elapsed().as_secs_f64() * 1e3 / iters as f64;
            fke_rows.push((variant, native as f64 / (mean_ms / 1e3), mean_ms));
        }
        for (i, (variant, tput, mean)) in fke_rows.iter().enumerate() {
            let gain = if i == fke_rows.len() - 1 && fke_rows.len() > 1 {
                format!(
                    "{} tput, {} lat",
                    table::ratio(*tput, fke_rows[0].1),
                    table::ratio(fke_rows[0].2, *mean)
                )
            } else {
                String::new()
            };
            t.row(&[
                if i == 0 { "FKE (pure compute)".into() } else { String::new() },
                variant.to_string(),
                table::kthroughput(*tput),
                table::ms(*mean),
                gain,
            ]);
        }
    }

    // ---- DSO stage ----
    if args.wants("dso") {
        eprintln!("[overall] DSO stage ...");
        let mix = WorkloadConfig::uniform_mix(&model_cfg.m_profiles);
        let implicit_cfg = {
            let mut c = StackConfig::default();
            c.dso.mode = DsoMode::ImplicitPad;
            c.pda.cache_mode = CacheMode::Async;
            c
        };
        let explicit_cfg = {
            let mut c = StackConfig::default();
            c.dso.mode = DsoMode::Explicit;
            c.pda.cache_mode = CacheMode::Async;
            c
        };
        let (t_im, l_im) = drive_stack(&manifest, &scenario, implicit_cfg, mix.clone(), seconds);
        let (t_ex, l_ex) = drive_stack(&manifest, &scenario, explicit_cfg, mix, seconds);
        t.row(&[
            "DSO (mixed M)".into(),
            "implicit shape".into(),
            table::kthroughput(t_im),
            table::ms(l_im),
            String::new(),
        ]);
        t.row(&[
            String::new(),
            "explicit shape".into(),
            table::kthroughput(t_ex),
            table::ms(l_ex),
            format!("{} tput, {} lat", table::ratio(t_ex, t_im), table::ratio(l_im, l_ex)),
        ]);
    }

    t.footnote("paper gains: PDA 1.9x/1.7x, FKE 6.3x/6.1x (long), DSO 1.3x/2.3x — CPU testbed compares shape");
    t.print();
}
