//! Storm bench — per-tenant cost of a flash crowd on the sim cluster,
//! with and without the feedback overload controller. One seeded storm
//! timeline (diurnal swell + tenant-1 flash crowd on a hot candidate
//! set + feature-invalidation burst) replays against both arms through
//! the timed driver, so the comparison is storm-for-storm identical.
//! Each row reports a tenant's p50/p99 latency, shed count, SLA-miss
//! rate, and quality-ladder mix. Every run emits machine-readable
//! `BENCH_storm.json`.
//!
//! The headline contract: with the controller armed, the flash tenant
//! absorbs the overload (gate sheds + truncations) while the quiet
//! tenant's miss rate stays near baseline; with it off, the bystander
//! pays. `--smoke` shrinks the timeline to a CI-sized run that still
//! gates on the controller engaging against the flash tenant only.

use std::collections::BTreeMap;
use std::sync::Arc;

use flame::benchkit::Table;
use flame::chaos::{ServeQuality, QUALITY_RUNGS};
use flame::cluster::{
    ClusterConfig, ClusterRouter, ReplicaBackend, RoutePolicy, SimConfig, SimReplica, TenantSet,
};
use flame::config::WorkloadConfig;
use flame::metrics::TenantCounts;
use flame::util::json::Json;
use flame::workload::storm::StormSpec;
use flame::workload::trace::TraceEvent;
use flame::workload::{driver, Generator, MAX_TENANTS};

const OUT_PATH: &str = "BENCH_storm.json";
const SEED: u64 = 41;
const REPLICAS: usize = 2;
const SLOTS: usize = 2;
const SERVICE_US: u64 = 2_500;
const DEADLINE_MS: u64 = 20;

struct ArmResult {
    controller: bool,
    submitted: u64,
    completed: u64,
    rejected: u64,
    tenants: [TenantCounts; MAX_TENANTS],
    admission_shed: u64,
    ticks: u64,
}

/// Replay the identical timeline against a fresh 2x2-slot sim cluster
/// (~1600 req/s capacity at 2.5 ms service) with the controller on or
/// off. Fresh routers per arm: cumulative tenant views are per-arm.
fn run_arm(controller: bool, events: &[TraceEvent]) -> ArmResult {
    let sim = SimConfig {
        base_us: SERVICE_US,
        per_pair_ns: 0,
        miss_penalty_us: 0,
        slots: SLOTS,
        ..SimConfig::default()
    };
    let backends: Vec<Arc<dyn ReplicaBackend>> = (0..REPLICAS)
        .map(|_| Arc::new(SimReplica::new(sim.clone())) as Arc<dyn ReplicaBackend>)
        .collect();
    let cfg = ClusterConfig {
        policy: RoutePolicy::LeastLoaded,
        deadline_ms: DEADLINE_MS,
        slots_per_replica: SLOTS,
        controller,
        tenants: TenantSet::parse("t0:w=2,t1:w=1").expect("tenant spec"),
        ..ClusterConfig::default()
    };
    let router = Arc::new(ClusterRouter::new(backends, cfg).expect("router"));
    let report = driver::open_loop_events(
        events,
        1.0,
        64,
        |r| router.submit(r).is_ok(),
        |u| {
            router.invalidate_user(u);
        },
    );
    ArmResult {
        controller,
        submitted: report.submitted,
        completed: report.completed,
        rejected: report.rejected,
        tenants: router.metrics.tenant_counts(),
        admission_shed: router.admission.shed(),
        ticks: router.controller().map_or(0, |c| c.ticks()),
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (base_rate, duration_s, spec_text) = if smoke {
        (500.0, 2.5, "flash:tenant=1,at_s=0.8,for_s=1.2,x=9,hot=64,mix:w0=2,w1=1")
    } else {
        (
            600.0,
            6.0,
            "diurnal:period_s=6,amp=0.3,flash:tenant=1,at_s=2,for_s=2.5,x=9,hot=64,\
             invalidate:rate=200,at_s=2,for_s=2.5,mix:w0=2,w1=1",
        )
    };
    let spec = StormSpec::parse(spec_text).expect("storm spec");
    let wl = WorkloadConfig {
        catalog_size: 10_000,
        zipf_theta: 0.99,
        n_users: 2_000,
        candidate_mix: vec![(16, 1.0)],
        arrival_rate: None,
        seed: SEED,
    };
    let events = spec.generate(&mut Generator::new(&wl, 16), base_rate, duration_s, SEED);
    println!(
        "storm isolation: {} events over {duration_s:.1}s @ {base_rate:.0}/s base, \
         {REPLICAS}x{SLOTS}-slot sim cluster ({SERVICE_US} µs service, {DEADLINE_MS} ms SLA), seed {SEED}",
        events.len()
    );
    println!("  spec: {spec_text}");

    let arms = [run_arm(false, &events), run_arm(true, &events)];

    let mut table = Table::new(
        "per-tenant storm cost (identical timeline, controller off vs on)",
        &[
            "arm", "tenant", "requests", "shed", "miss %", "p50 ms", "p99 ms", "full", "trunc",
            "shed q",
        ],
    );
    for arm in &arms {
        let label = if arm.controller { "on" } else { "off" };
        for (i, tc) in arm.tenants.iter().enumerate() {
            if tc.submitted() == 0 {
                continue;
            }
            table.row(&[
                label.to_string(),
                i.to_string(),
                tc.requests.to_string(),
                tc.shed.to_string(),
                format!("{:.1}", tc.miss_rate() * 100.0),
                format!("{:.2}", tc.overall_p50_us as f64 / 1_000.0),
                format!("{:.2}", tc.overall_p99_us as f64 / 1_000.0),
                tc.quality[ServeQuality::Full.index()].to_string(),
                tc.quality[ServeQuality::TruncatedCandidates.index()].to_string(),
                tc.quality[ServeQuality::Shed.index()].to_string(),
            ]);
        }
    }
    table.footnote("quality columns count responses per degradation-ladder rung");
    table.print();

    // CI gates: the storm overloads the open-loop arm, and the armed
    // controller engages against the flash tenant (gate sheds and/or
    // truncations land on tenant 1, the one causing the overload)
    let (off, on) = (&arms[0], &arms[1]);
    assert!(
        off.admission_shed + off.tenants[0].sla_miss + off.tenants[1].sla_miss > 0,
        "the storm never overloaded the open-loop arm — raise the flash multiplier"
    );
    assert!(on.ticks > 0, "controller arm never ticked");
    let flash_degraded = on.tenants[1].shed
        + on.tenants[1].quality[ServeQuality::TruncatedCandidates.index()];
    assert!(
        flash_degraded > 0,
        "controller never degraded the flash tenant (shed {} trunc {})",
        on.tenants[1].shed,
        on.tenants[1].quality[ServeQuality::TruncatedCandidates.index()]
    );

    let mut arms_json = BTreeMap::new();
    for arm in &arms {
        let mut tenants_json = BTreeMap::new();
        for (i, tc) in arm.tenants.iter().enumerate() {
            if tc.submitted() == 0 {
                continue;
            }
            let mut o = BTreeMap::new();
            o.insert("requests".into(), Json::Num(tc.requests as f64));
            o.insert("shed".into(), Json::Num(tc.shed as f64));
            o.insert("sla_miss".into(), Json::Num(tc.sla_miss as f64));
            o.insert("p50_us".into(), Json::Num(tc.overall_p50_us as f64));
            o.insert("p99_us".into(), Json::Num(tc.overall_p99_us as f64));
            let mut q = BTreeMap::new();
            for r in 0..QUALITY_RUNGS {
                let rung = ServeQuality::from_index(r).expect("rung index");
                q.insert(rung.as_str().to_string(), Json::Num(tc.quality[r] as f64));
            }
            o.insert("quality".into(), Json::Obj(q));
            tenants_json.insert(format!("t{i}"), Json::Obj(o));
        }
        let mut a = BTreeMap::new();
        a.insert("submitted".into(), Json::Num(arm.submitted as f64));
        a.insert("completed".into(), Json::Num(arm.completed as f64));
        a.insert("rejected".into(), Json::Num(arm.rejected as f64));
        a.insert("admission_shed".into(), Json::Num(arm.admission_shed as f64));
        a.insert("controller_ticks".into(), Json::Num(arm.ticks as f64));
        a.insert("tenants".into(), Json::Obj(tenants_json));
        arms_json.insert(
            if arm.controller { "controller_on" } else { "controller_off" }.to_string(),
            Json::Obj(a),
        );
    }
    let mut top = BTreeMap::new();
    top.insert("bench".into(), Json::Str("storm".into()));
    top.insert("backend".into(), Json::Str("sim-cluster".into()));
    top.insert("smoke".into(), Json::Bool(smoke));
    top.insert("seed".into(), Json::Num(SEED as f64));
    top.insert("spec".into(), Json::Str(spec_text.to_string()));
    top.insert("base_rate".into(), Json::Num(base_rate));
    top.insert("duration_s".into(), Json::Num(duration_s));
    top.insert("events".into(), Json::Num(events.len() as f64));
    top.insert("tenant_spec".into(), Json::Str("t0:w=2,t1:w=1".into()));
    top.insert("arms".into(), Json::Obj(arms_json));
    match std::fs::write(OUT_PATH, Json::Obj(top).to_string()) {
        Ok(()) => eprintln!("  wrote {OUT_PATH}"),
        Err(e) => eprintln!("  could not write {OUT_PATH}: {e}"),
    }
}
