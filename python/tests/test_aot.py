"""AOT path: HLO text lowering, weight-blob format, test-vector
container, and manifest consistency — the python half of the rust/python
contract (the rust half is rust/tests/e2e_tiny.rs)."""

import json
import os
import struct
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile.config import SCENARIOS, model_flops
from compile.model import make_flat_fn
from compile.params import flatten_params, flatten_spec, init_params, save_weights_bin

jax.config.update("jax_platform_name", "cpu")

CFG = SCENARIOS["tiny"]


class TestLowering:
    @pytest.mark.parametrize("variant", ["naive", "api", "fused"])
    def test_hlo_text_produced(self, variant):
        text = aot.lower_model(CFG, variant, 4)
        assert "HloModule" in text
        assert "ENTRY" in text
        # parameters: all weights + hist + cands
        n_params = len(flatten_spec(CFG)) + 2
        assert text.count("parameter(") >= n_params

    def test_hlo_has_no_giant_constants(self):
        """Weights are runtime parameters, not baked constants — the HLO
        text must stay small (the whole point of the weights.bin split)."""
        text = aot.lower_model(CFG, "api", 4)
        assert len(text) < 2_000_000, f"HLO text {len(text)} bytes"

    def test_scan_vs_unroll_structure(self):
        """The api variant scans layers (one while loop); naive unrolls
        (bigger graph) — the ONNX-verbosity pathology is real in the IR."""
        api = aot.lower_model(CFG, "api", 4)
        naive = aot.lower_model(CFG, "naive", 4)
        assert "while" in api
        assert len(naive) > len(api)


class TestWeightsBin:
    def test_save_and_size(self):
        params = init_params(CFG)
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "w.bin")
            nbytes = save_weights_bin(CFG, params, path)
            assert os.path.getsize(path) == nbytes
            expect = sum(
                4 * int(np.prod(s)) for _, s in flatten_spec(CFG)
            )
            assert nbytes == expect

    def test_byte_order_little_endian_f32(self):
        params = init_params(CFG)
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "w.bin")
            save_weights_bin(CFG, params, path)
            raw = np.fromfile(path, dtype="<f4")
            # first tensor in canonical order is block0.qkv_w
            first = np.asarray(params["block0.qkv_w"]).ravel()
            np.testing.assert_array_equal(raw[: first.size], first)


class TestTestVectors:
    def test_container_format(self):
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "tv.bin")
            a = np.arange(6, dtype=np.float32).reshape(2, 3)
            aot.write_testvector(path, [("x", a)])
            raw = open(path, "rb").read()
            magic, version, count = struct.unpack("<III", raw[:12])
            assert magic == aot.TV_MAGIC
            assert version == 1 and count == 1
            # name
            (nlen,) = struct.unpack("<I", raw[12:16])
            assert raw[16 : 16 + nlen] == b"x"

    def test_values_roundtrip_via_numpy(self):
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "tv.bin")
            a = np.random.RandomState(0).randn(4, 5).astype(np.float32)
            aot.write_testvector(path, [("t", a)])
            raw = open(path, "rb").read()
            data = np.frombuffer(raw[-a.nbytes:], dtype="<f4").reshape(4, 5)
            np.testing.assert_array_equal(data, a)


class TestManifestBuild:
    def test_full_tiny_build(self):
        """Run the real aot main on tiny into a temp dir and check the
        manifest is complete + self-consistent."""
        with tempfile.TemporaryDirectory() as d:
            aot.main(["--out-dir", d, "--scenarios", "tiny", "--testvectors", "1"])
            manifest = json.load(open(os.path.join(d, "manifest.json")))
            assert "tiny" in manifest["scenarios"]
            sc = manifest["scenarios"]["tiny"]
            assert os.path.exists(os.path.join(d, sc["weights_file"]))
            assert sc["weights_bytes"] == os.path.getsize(os.path.join(d, sc["weights_file"]))
            # engines: naive@native + api/fused at both profiles = 5
            entries = [e for e in manifest["models"] if e["scenario"] == "tiny"]
            assert len(entries) == 5
            for e in entries:
                assert os.path.exists(os.path.join(d, e["path"]))
                assert e["flops"] == model_flops(CFG, e["m"])
            tvs = [t for t in manifest["testvectors"] if t["scenario"] == "tiny"]
            assert len(tvs) == 5  # one per engine
            for t in tvs:
                assert os.path.exists(os.path.join(d, t["path"]))

    def test_incremental_merge_preserves_other_scenarios(self):
        with tempfile.TemporaryDirectory() as d:
            aot.main(["--out-dir", d, "--scenarios", "tiny", "--testvectors", "0",
                      "--variants", "api"])
            aot.main(["--out-dir", d, "--scenarios", "tiny", "--testvectors", "0",
                      "--variants", "fused"])
            manifest = json.load(open(os.path.join(d, "manifest.json")))
            variants = {e["variant"] for e in manifest["models"]}
            assert variants == {"api", "fused"}


class TestExecutedOutputs:
    def test_jit_fn_matches_eager(self):
        params = init_params(CFG)
        flat = flatten_params(CFG, params)
        fn = jax.jit(make_flat_fn(CFG, "fused"))
        k = jax.random.PRNGKey(1)
        hist = jax.random.normal(k, (CFG.seq_len, CFG.d_model), jnp.float32)
        cands = jax.random.normal(jax.random.fold_in(k, 1), (8, CFG.d_model), jnp.float32)
        (jitted,) = fn(*flat, hist, cands)
        (eager,) = make_flat_fn(CFG, "fused")(*flat, hist, cands)
        np.testing.assert_allclose(jitted, eager, atol=1e-6, rtol=1e-5)
