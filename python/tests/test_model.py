"""L2 model correctness: the three engine variants vs the oracle,
model-level semantics (SUMI isolation, gating, multi-task heads), and
shape sweeps across scenarios/profiles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.config import SCENARIOS
from compile.kernels.ref import model_ref
from compile.model import make_flat_fn, model_forward
from compile.naive import model_forward_naive
from compile.params import (
    flatten_params,
    flatten_spec,
    init_params,
    unflatten_params,
)

jax.config.update("jax_platform_name", "cpu")

CFG = SCENARIOS["tiny"]


@pytest.fixture(scope="module")
def params():
    return init_params(CFG)


def inputs(m, seed=0):
    k = jax.random.PRNGKey(seed)
    hist = jax.random.normal(k, (CFG.seq_len, CFG.d_model), jnp.float32)
    cands = jax.random.normal(jax.random.fold_in(k, 1), (m, CFG.d_model), jnp.float32)
    return hist, cands


class TestVariantEquivalence:
    @pytest.mark.parametrize("m", [4, 8])
    def test_api_matches_ref(self, params, m):
        hist, cands = inputs(m)
        np.testing.assert_allclose(
            model_forward(CFG, params, hist, cands, "api"),
            model_ref(CFG, params, hist, cands),
            atol=5e-6, rtol=5e-5,
        )

    @pytest.mark.parametrize("m", [4, 8])
    def test_fused_matches_ref(self, params, m):
        hist, cands = inputs(m)
        np.testing.assert_allclose(
            model_forward(CFG, params, hist, cands, "fused"),
            model_ref(CFG, params, hist, cands),
            atol=5e-6, rtol=5e-5,
        )

    @pytest.mark.parametrize("m", [4, 8])
    def test_naive_matches_ref(self, params, m):
        hist, cands = inputs(m)
        np.testing.assert_allclose(
            model_forward_naive(CFG, params, hist, cands),
            model_ref(CFG, params, hist, cands),
            atol=5e-6, rtol=5e-5,
        )

    def test_bench_scenario_variants_agree(self):
        cfg = SCENARIOS["bench"]
        p = init_params(cfg)
        k = jax.random.PRNGKey(5)
        hist = jax.random.normal(k, (cfg.seq_len, cfg.d_model), jnp.float32)
        cands = jax.random.normal(jax.random.fold_in(k, 1), (16, cfg.d_model), jnp.float32)
        r = model_ref(cfg, p, hist, cands)
        for out in (
            model_forward(cfg, p, hist, cands, "api"),
            model_forward(cfg, p, hist, cands, "fused"),
            model_forward_naive(cfg, p, hist, cands),
        ):
            np.testing.assert_allclose(out, r, atol=1e-5, rtol=1e-4)


class TestModelSemantics:
    def test_output_shape_and_range(self, params):
        hist, cands = inputs(8)
        out = model_ref(CFG, params, hist, cands)
        assert out.shape == (8, CFG.n_tasks)
        assert bool(jnp.all((out >= 0) & (out <= 1)))

    def test_candidate_isolation_end_to_end(self, params):
        """Scores of candidate i are independent of candidate j != i —
        the SUMI property must survive the whole model, not just the
        attention kernel."""
        hist, cands = inputs(8, seed=3)
        base = model_ref(CFG, params, hist, cands)
        cands2 = cands.at[5].add(3.0)
        pert = model_ref(CFG, params, hist, cands2)
        np.testing.assert_allclose(pert[:5], base[:5], atol=1e-6)
        np.testing.assert_allclose(pert[6:], base[6:], atol=1e-6)
        assert float(jnp.max(jnp.abs(pert[5] - base[5]))) > 1e-4

    def test_candidate_permutation_equivariance(self, params):
        """Permuting candidates permutes scores identically."""
        hist, cands = inputs(8, seed=4)
        perm = jnp.array([3, 1, 7, 0, 5, 2, 6, 4])
        out = model_ref(CFG, params, hist, cands)
        out_p = model_ref(CFG, params, hist, cands[perm])
        np.testing.assert_allclose(out_p, out[perm], atol=1e-5)

    def test_history_affects_scores(self, params):
        # non-uniform perturbation (uniform per-row shifts are invisible
        # to LayerNorm — see test_uniform_history_shift_is_invariant)
        hist, cands = inputs(8, seed=6)
        a = model_ref(CFG, params, hist, cands)
        b = model_ref(CFG, params, hist * 1.5 + 0.3, cands)
        assert float(jnp.max(jnp.abs(a - b))) > 1e-4

    def test_uniform_history_shift_is_invariant(self, params):
        """LayerNorm makes per-row additive constants invisible, and
        history reaches candidates only through LN'd K/V — a uniform
        shift must NOT change scores (regression guard: if candidate
        rows leaked the raw shift, this would fail)."""
        hist, cands = inputs(8, seed=6)
        a = model_ref(CFG, params, hist, cands)
        b = model_ref(CFG, params, hist + 0.5, cands)
        assert float(jnp.max(jnp.abs(a - b))) < 1e-5

    def test_blocks_see_different_history_halves(self, params):
        """Perturbing the first history half changes scores differently
        than the second half (the block split is real)."""
        hist, cands = inputs(8, seed=7)
        lb = CFG.block_len
        base = model_ref(CFG, params, hist, cands)
        a = model_ref(CFG, params, hist.at[:lb].multiply(1.7), cands)
        b = model_ref(CFG, params, hist.at[lb:].multiply(1.7), cands)
        assert float(jnp.max(jnp.abs(a - base))) > 1e-5
        assert float(jnp.max(jnp.abs(b - base))) > 1e-5
        assert float(jnp.max(jnp.abs(a - b))) > 1e-5


class TestParams:
    def test_flatten_roundtrip(self, params):
        flat = flatten_params(CFG, params)
        back = unflatten_params(CFG, flat)
        assert set(back) == set(params)
        for k in params:
            np.testing.assert_array_equal(back[k], params[k])

    def test_spec_shapes_match_init(self, params):
        for name, shape in flatten_spec(CFG):
            assert tuple(params[name].shape) == tuple(shape), name

    def test_deterministic_init(self):
        a = init_params(CFG)
        b = init_params(CFG)
        for k in a:
            np.testing.assert_array_equal(a[k], b[k])

    def test_different_scenarios_different_weights(self):
        a = init_params(SCENARIOS["tiny"])
        # same shapes would be needed to compare; just check seeds differ
        assert SCENARIOS["tiny"].seed != SCENARIOS["bench"].seed

    def test_flat_fn_signature(self, params):
        fn = make_flat_fn(CFG, "api")
        flat = flatten_params(CFG, params)
        hist, cands = inputs(4)
        (out,) = fn(*flat, hist, cands)
        np.testing.assert_allclose(
            out, model_ref(CFG, params, hist, cands), atol=5e-6, rtol=5e-5
        )

    def test_flat_fn_naive_same_weights(self, params):
        """All variants consume the identical flat tuple."""
        flat = flatten_params(CFG, params)
        hist, cands = inputs(4)
        outs = [make_flat_fn(CFG, v)(*flat, hist, cands)[0] for v in ("naive", "api", "fused")]
        for o in outs[1:]:
            np.testing.assert_allclose(o, outs[0], atol=5e-6, rtol=5e-5)
