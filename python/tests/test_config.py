"""Scenario/config invariants + the FLOP formulas (whose values the rust
side re-derives and cross-checks through the manifest)."""

import pytest

from compile.config import (
    SCENARIOS,
    VARIANTS,
    masked_attention_score_flops,
    model_flops,
)


class TestScenarios:
    def test_all_validate(self):
        for cfg in SCENARIOS.values():
            cfg.validate()

    def test_paper_table2_shape(self):
        base, long = SCENARIOS["base"], SCENARIOS["long"]
        assert base.seq_len == 512 and base.native_m == 128
        assert long.seq_len == 1024 and long.native_m == 512
        assert base.layers_per_block == 12 and base.n_blocks == 2

    def test_flops_orders_of_magnitude(self):
        # paper Table 2: base 3.72e9, long 1.64e10 (we're within ~1.5x
        # using D=128 instead of the implied ~100)
        fb = model_flops(SCENARIOS["base"], 128)
        fl = model_flops(SCENARIOS["long"], 512)
        assert 1e9 < fb < 1e10
        assert 1e10 < fl < 1e11

    def test_tiny_flops_constant(self):
        # the value hard-coded in rust config/flops.rs tests
        assert model_flops(SCENARIOS["tiny"], 8) == 2_791_424

    def test_block_len_divides(self):
        for cfg in SCENARIOS.values():
            assert cfg.block_len * cfg.n_blocks == cfg.seq_len
            assert cfg.head_dim * cfg.n_heads == cfg.d_model

    def test_profiles_cover_native(self):
        for cfg in SCENARIOS.values():
            assert cfg.native_m in cfg.m_profiles
            assert list(cfg.m_profiles) == sorted(cfg.m_profiles)

    def test_variants_list(self):
        assert VARIANTS == ("naive", "api", "fused")


class TestMaskedFlops:
    def test_masked_below_dense(self):
        cfg = SCENARIOS["long"]
        m = 512
        n = cfg.n_tokens(m)
        dense = 4 * n * n * cfg.d_model
        masked = masked_attention_score_flops(cfg, m)
        assert masked < dense
        # candidate x candidate region dead: roughly half at m = block_len
        assert masked / dense < 0.6

    def test_monotone_in_m(self):
        cfg = SCENARIOS["bench"]
        vals = [masked_attention_score_flops(cfg, m) for m in cfg.m_profiles]
        assert vals == sorted(vals)
