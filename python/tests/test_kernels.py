"""L1 kernel correctness: pallas kernels vs the pure-jnp oracle,
including hypothesis sweeps over shapes/dtypes — the CORE correctness
signal for the FKE plug-ins."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.flash_attention import (
    attention_tile_stats,
    flash_attention,
    _choose_block,
)
from compile.kernels.fused_ffn import fused_ln_ffn, ffn_vmem_bytes

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


class TestFlashAttention:
    @pytest.mark.parametrize("hist,m", [(16, 4), (16, 8), (32, 4), (64, 16), (8, 8)])
    @pytest.mark.parametrize("heads,hd", [(2, 8), (4, 16)])
    def test_matches_ref(self, hist, m, heads, hd):
        n = hist + m
        q, k, v = (rand(i, (heads, n, hd)) for i in range(3))
        temp = jnp.float32(0.9)
        out_ref = ref.attention_ref(q, k, v, ref.mask_bias(hist, m), temp)
        out = flash_attention(q, k, v, temp, hist_len=hist)
        np.testing.assert_allclose(out, out_ref, atol=2e-6, rtol=2e-5)

    def test_temperature_traced(self):
        # temperature is a traced (learned) scalar — results must vary
        hist, m, heads, hd = 16, 4, 2, 8
        q, k, v = (rand(i + 10, (heads, hist + m, hd)) for i in range(3))
        a = flash_attention(q, k, v, jnp.float32(0.5), hist_len=hist)
        b = flash_attention(q, k, v, jnp.float32(2.0), hist_len=hist)
        assert float(jnp.max(jnp.abs(a - b))) > 1e-3

    def test_candidates_isolated(self):
        """The SUMI guarantee: perturbing candidate j never changes
        candidate i's output (they must not attend to each other)."""
        hist, m, heads, hd = 16, 4, 2, 8
        n = hist + m
        q, k, v = (rand(i + 20, (heads, n, hd)) for i in range(3))
        temp = jnp.float32(1.0)
        base = flash_attention(q, k, v, temp, hist_len=hist)
        # perturb candidate 3 (row hist+3) in k and v
        k2 = k.at[:, hist + 3, :].add(10.0)
        v2 = v.at[:, hist + 3, :].add(10.0)
        pert = flash_attention(q, k2, v2, temp, hist_len=hist)
        # candidates 0..2 and all history rows unchanged
        np.testing.assert_allclose(
            pert[:, : hist + 3, :], base[:, : hist + 3, :], atol=1e-6
        )
        # candidate 3 itself changes (it sees its own k/v)
        assert float(jnp.max(jnp.abs(pert[:, hist + 3] - base[:, hist + 3]))) > 1e-3

    def test_history_causal(self):
        """Perturbing a future history token must not change earlier rows."""
        hist, m, heads, hd = 16, 4, 2, 8
        n = hist + m
        q, k, v = (rand(i + 30, (heads, n, hd)) for i in range(3))
        temp = jnp.float32(1.0)
        base = flash_attention(q, k, v, temp, hist_len=hist)
        k2 = k.at[:, 10, :].add(5.0)
        v2 = v.at[:, 10, :].add(5.0)
        pert = flash_attention(q, k2, v2, temp, hist_len=hist)
        np.testing.assert_allclose(pert[:, :10, :], base[:, :10, :], atol=1e-6)

    def test_explicit_block_size(self):
        hist, m, heads, hd = 16, 8, 2, 8
        q, k, v = (rand(i + 40, (heads, hist + m, hd)) for i in range(3))
        temp = jnp.float32(1.0)
        ref_out = ref.attention_ref(q, k, v, ref.mask_bias(hist, m), temp)
        for block in (4, 8):
            out = flash_attention(q, k, v, temp, hist_len=hist, block=block)
            np.testing.assert_allclose(out, ref_out, atol=2e-6, rtol=2e-5)

    def test_rejects_bad_block(self):
        q = k = v = jnp.zeros((1, 20, 8))
        with pytest.raises(AssertionError):
            flash_attention(q, k, v, jnp.float32(1.0), hist_len=16, block=8)

    @settings(max_examples=20, deadline=None)
    @given(
        hist_tiles=st.integers(1, 4),
        m_tiles=st.integers(1, 3),
        block=st.sampled_from([4, 8]),
        heads=st.integers(1, 3),
        hd=st.sampled_from([4, 8, 16]),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_shape_sweep(self, hist_tiles, m_tiles, block, heads, hd, seed):
        hist, m = hist_tiles * block, m_tiles * block
        n = hist + m
        key = jax.random.PRNGKey(seed)
        ks = jax.random.split(key, 3)
        q, k, v = (jax.random.normal(kk, (heads, n, hd), jnp.float32) for kk in ks)
        temp = jnp.float32(0.5 + (seed % 100) / 50.0)
        out = flash_attention(q, k, v, temp, hist_len=hist, block=block)
        expect = ref.attention_ref(q, k, v, ref.mask_bias(hist, m), temp)
        np.testing.assert_allclose(out, expect, atol=5e-6, rtol=5e-5)

    def test_tile_stats_accounting(self):
        s = attention_tile_stats(16, 4)
        assert s == {"block": 4, "visited_tiles": 15, "total_tiles": 25,
                     "flop_fraction": 0.6}
        # more candidates -> lower visited fraction (the mask-aware win)
        f1 = attention_tile_stats(512, 128)["flop_fraction"]
        f2 = attention_tile_stats(512, 512)["flop_fraction"]
        assert f2 < f1

    def test_choose_block_divides(self):
        for hist, m in [(16, 4), (512, 128), (512, 1024), (64, 16)]:
            b = _choose_block(hist, m)
            assert hist % b == 0 and m % b == 0 and b <= 128


class TestFusedFfn:
    @pytest.mark.parametrize("n,d,f", [(8, 16, 64), (20, 16, 64), (32, 32, 128)])
    def test_matches_ref(self, n, d, f):
        x = rand(1, (n, d))
        lns, lnb = rand(2, (d,)) * 0.1 + 1.0, rand(3, (d,)) * 0.1
        w1, b1 = rand(4, (d, f), 0.2), rand(5, (f,), 0.1)
        w2, b2 = rand(6, (f, d), 0.2), rand(7, (d,), 0.1)
        out = fused_ln_ffn(x, lns, lnb, w1, b1, w2, b2)
        expect = ref.ln_ffn_ref(x, lns, lnb, w1, b1, w2, b2)
        np.testing.assert_allclose(out, expect, atol=2e-6, rtol=2e-5)

    def test_includes_residual(self):
        n, d, f = 8, 16, 64
        x = rand(11, (n, d))
        zeros_w1 = jnp.zeros((d, f))
        out = fused_ln_ffn(x, jnp.ones(d), jnp.zeros(d), zeros_w1,
                           jnp.zeros(f), jnp.zeros((f, d)), jnp.zeros(d))
        # zero FFN weights: gelu(0)=0 -> output == residual input... plus b2=0
        np.testing.assert_allclose(out, x, atol=1e-6)

    @settings(max_examples=15, deadline=None)
    @given(
        n_tiles=st.integers(1, 6),
        block=st.sampled_from([2, 4, 8]),
        d=st.sampled_from([8, 16]),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_rows_sweep(self, n_tiles, block, d, seed):
        n, f = n_tiles * block, 4 * d
        key = jax.random.PRNGKey(seed)
        ks = jax.random.split(key, 7)
        x = jax.random.normal(ks[0], (n, d), jnp.float32)
        lns = 1.0 + 0.1 * jax.random.normal(ks[1], (d,), jnp.float32)
        lnb = 0.1 * jax.random.normal(ks[2], (d,), jnp.float32)
        w1 = 0.2 * jax.random.normal(ks[3], (d, f), jnp.float32)
        b1 = 0.1 * jax.random.normal(ks[4], (f,), jnp.float32)
        w2 = 0.2 * jax.random.normal(ks[5], (f, d), jnp.float32)
        b2 = 0.1 * jax.random.normal(ks[6], (d,), jnp.float32)
        out = fused_ln_ffn(x, lns, lnb, w1, b1, w2, b2, block_n=block)
        expect = ref.ln_ffn_ref(x, lns, lnb, w1, b1, w2, b2)
        np.testing.assert_allclose(out, expect, atol=5e-6, rtol=5e-5)

    def test_vmem_budget(self):
        # D=128 F=512 block 128: ~1.3 MB, far under 16 MB VMEM
        assert ffn_vmem_bytes(1024, 128, 512) < 16 << 20


class TestFusedHead:
    def _weights(self, nb, d, f, t, seed=0):
        ks = jax.random.split(jax.random.PRNGKey(seed), 6)
        nbd = nb * d
        return dict(
            gate_w=0.2 * jax.random.normal(ks[0], (nbd, nbd), jnp.float32),
            gate_b=0.1 * jax.random.normal(ks[1], (nbd,), jnp.float32),
            exp_w1=0.2 * jax.random.normal(ks[2], (d, f), jnp.float32),
            exp_b1=0.1 * jax.random.normal(ks[3], (f,), jnp.float32),
            exp_w2=0.2 * jax.random.normal(ks[4], (f, t), jnp.float32),
            exp_b2=0.1 * jax.random.normal(ks[5], (t,), jnp.float32),
        )

    def _ref(self, cat, w, nb, d):
        m = cat.shape[0]
        logits = cat @ w["gate_w"] + w["gate_b"]
        gates = jax.nn.softmax(logits.reshape(m, nb, d), axis=1)
        fused = jnp.sum(gates * cat.reshape(m, nb, d), axis=1)
        h = jax.nn.gelu(fused @ w["exp_w1"] + w["exp_b1"], approximate=False)
        return jax.nn.sigmoid(h @ w["exp_w2"] + w["exp_b2"])

    @pytest.mark.parametrize("m,nb,d,f,t", [(8, 2, 16, 64, 3), (16, 2, 32, 128, 3), (4, 3, 8, 32, 2)])
    def test_matches_ref(self, m, nb, d, f, t):
        from compile.kernels.fused_head import fused_head
        w = self._weights(nb, d, f, t)
        cat = rand(9, (m, nb * d))
        out = fused_head(cat, w["gate_w"], w["gate_b"], w["exp_w1"],
                         w["exp_b1"], w["exp_w2"], w["exp_b2"],
                         n_blocks=nb, d_model=d)
        np.testing.assert_allclose(out, self._ref(cat, w, nb, d), atol=2e-6, rtol=2e-5)

    def test_outputs_are_probabilities(self):
        from compile.kernels.fused_head import fused_head
        w = self._weights(2, 16, 64, 3)
        cat = rand(10, (8, 32), 3.0)
        out = fused_head(cat, w["gate_w"], w["gate_b"], w["exp_w1"],
                         w["exp_b1"], w["exp_w2"], w["exp_b2"],
                         n_blocks=2, d_model=16)
        assert bool(jnp.all((out >= 0) & (out <= 1)))

    @settings(max_examples=10, deadline=None)
    @given(m_tiles=st.integers(1, 4), block=st.sampled_from([2, 4]),
           seed=st.integers(0, 2**16))
    def test_hypothesis_row_sweep(self, m_tiles, block, seed):
        from compile.kernels.fused_head import fused_head
        nb, d, f, t = 2, 8, 32, 3
        m = m_tiles * block
        w = self._weights(nb, d, f, t, seed=seed)
        cat = jax.random.normal(jax.random.PRNGKey(seed + 1), (m, nb * d), jnp.float32)
        out = fused_head(cat, w["gate_w"], w["gate_b"], w["exp_w1"],
                         w["exp_b1"], w["exp_w2"], w["exp_b2"],
                         n_blocks=nb, d_model=d, block_m=block)
        np.testing.assert_allclose(out, self._ref(cat, w, nb, d), atol=5e-6, rtol=5e-5)

    def test_vmem_budget(self):
        from compile.kernels.fused_head import head_vmem_bytes
        assert head_vmem_bytes(2, 128, 512, 3) < 16 << 20


class TestMaskSemantics:
    def test_sumi_mask_structure(self):
        m = np.asarray(ref.sumi_mask(4, 2))
        # history causal
        assert m[0, 0] and not m[0, 1]
        assert m[3, :4].all()
        # history never sees candidates
        assert not m[:4, 4:].any()
        # candidates see all history + self only
        assert m[4, :4].all() and m[4, 4] and not m[4, 5]
        assert m[5, :4].all() and m[5, 5] and not m[5, 4]

    def test_every_row_has_visible_key(self):
        for hist, m in [(4, 2), (16, 8), (1, 1)]:
            mask = np.asarray(ref.sumi_mask(hist, m))
            assert mask.any(axis=1).all()

    def test_bias_values(self):
        b = np.asarray(ref.mask_bias(2, 1))
        assert b[0, 0] == 0.0
        assert b[0, 1] == ref.NEG_BIAS
