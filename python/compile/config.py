"""Model/scenario configuration for the Climber-like GR model (L2).

Mirrors `rust/src/config/model.rs` — keep the two in sync. The scenarios
reproduce the paper's Table 2 (`base`, `long`) plus two scaled tiers
(`tiny` for tests, `bench` for CI-speed benches); see DESIGN.md §3.
"""

from dataclasses import dataclass, field
from typing import Dict, Tuple


@dataclass(frozen=True)
class ModelConfig:
    """Static architecture + scenario parameters of one served model.

    Attributes:
        name: scenario id (``tiny`` / ``bench`` / ``base`` / ``long``).
        seq_len: total user-history length ``L`` (split across blocks).
        n_blocks: number of independent Transformer blocks ``N_b``.
        layers_per_block: Transformer layers inside each block.
        d_model: hidden dimension ``D``.
        n_heads: attention heads (``D % n_heads == 0``).
        n_tasks: number of prediction tasks scored by the expert MLP.
        m_profiles: candidate-count profiles exported for DSO routing.
        native_m: the paper-native candidate count (Table 2 column).
        seed: weight-init seed (stable across variants, so all engine
            variants of a scenario share one ``weights_<name>.bin``).
    """

    name: str
    seq_len: int
    n_blocks: int
    layers_per_block: int
    d_model: int
    n_heads: int
    m_profiles: Tuple[int, ...]
    native_m: int
    n_tasks: int = 3
    seed: int = 0

    @property
    def block_len(self) -> int:
        """History tokens per block (``L / N_b``)."""
        assert self.seq_len % self.n_blocks == 0
        return self.seq_len // self.n_blocks

    @property
    def d_ff(self) -> int:
        """FFN inner dimension (4x, the usual Transformer ratio)."""
        return 4 * self.d_model

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def n_tokens(self, m: int) -> int:
        """Per-block sequence length: history chunk + M candidates."""
        return self.block_len + m

    def validate(self) -> None:
        assert self.seq_len % self.n_blocks == 0
        assert self.d_model % self.n_heads == 0
        assert self.native_m in self.m_profiles
        for m in self.m_profiles:
            assert m > 0


def attention_flops(n: int, d: int) -> int:
    """Dense-attention FLOPs for one layer over n tokens, hidden d.

    QKV projection (2*n*d*3d) + scores (2*n*n*d) + AV (2*n*n*d)
    + output projection (2*n*d*d).
    """
    return 2 * n * d * 3 * d + 2 * n * n * d + 2 * n * n * d + 2 * n * d * d


def ffn_flops(n: int, d: int, f: int) -> int:
    """FFN FLOPs for one layer: two GEMMs."""
    return 2 * n * d * f + 2 * n * f * d


def model_flops(cfg: ModelConfig, m: int) -> int:
    """Analytic per-request FLOPs of the dense forward (SUMI batch of M).

    This is the number the paper's Table 2 reports (its "FLOPS" column);
    the rust mirror lives in `config/flops.rs` and both are asserted
    equal through the manifest.
    """
    n = cfg.n_tokens(m)
    per_layer = attention_flops(n, cfg.d_model) + ffn_flops(n, cfg.d_model, cfg.d_ff)
    total = cfg.n_blocks * cfg.layers_per_block * per_layer
    # gating fusion: concat [M, nb*D] @ [nb*D, nb*D]
    total += 2 * m * (cfg.n_blocks * cfg.d_model) * (cfg.n_blocks * cfg.d_model)
    # expert MLP: [M, D] @ [D, F] @ [F, T]
    total += 2 * m * cfg.d_model * cfg.d_ff + 2 * m * cfg.d_ff * cfg.n_tasks
    return total


def masked_attention_score_flops(cfg: ModelConfig, m: int) -> int:
    """Score+AV FLOPs actually *needed* under the SUMI mask (per layer).

    History rows attend causally within history; candidate rows attend to
    history + self. This is what the mask-aware L1 kernel's tile-skip
    schedule approaches; the dense engines burn ``4*n^2*d`` instead.
    """
    lb, d = cfg.block_len, cfg.d_model
    hist = lb * (lb + 1) // 2          # causal history x history
    cand = m * (lb + 1)                # candidates x (history + self)
    return 4 * (hist + cand) * d


SCENARIOS: Dict[str, ModelConfig] = {
    "tiny": ModelConfig(
        name="tiny", seq_len=32, n_blocks=2, layers_per_block=2,
        d_model=32, n_heads=2, m_profiles=(4, 8), native_m=8, seed=1001,
    ),
    "bench": ModelConfig(
        name="bench", seq_len=128, n_blocks=2, layers_per_block=3,
        d_model=64, n_heads=4, m_profiles=(16, 32, 64, 128), native_m=32,
        seed=1002,
    ),
    # Paper Table 2 rows (D=128 instead of the implied ~100 for MXU-friendly
    # tiling; FLOPs stay within the paper's order of magnitude).
    "base": ModelConfig(
        name="base", seq_len=512, n_blocks=2, layers_per_block=12,
        d_model=128, n_heads=8, m_profiles=(32, 64, 128), native_m=128,
        seed=1003,
    ),
    "long": ModelConfig(
        name="long", seq_len=1024, n_blocks=2, layers_per_block=12,
        d_model=128, n_heads=8, m_profiles=(128, 256, 512, 1024),
        native_m=512, seed=1004,
    ),
}

VARIANTS = ("naive", "api", "fused")

for _cfg in SCENARIOS.values():
    _cfg.validate()
