"""Weight initialization + the canonical flat parameter order.

All engine variants of a scenario share one weight set; the flat order
defined by :func:`flatten_spec` is the contract with the rust runtime
(`rust/src/manifest`): `weights_<scenario>.bin` stores the tensors
concatenated as little-endian f32 in exactly this order, and every lowered
HLO takes them as its leading parameters in exactly this order.
"""

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig

# (name template, shape builder) per block, in order.
_BLOCK_TENSORS = (
    ("qkv_w", lambda c: (c.layers_per_block, c.d_model, 3 * c.d_model)),
    ("qkv_b", lambda c: (c.layers_per_block, 3 * c.d_model)),
    ("out_w", lambda c: (c.layers_per_block, c.d_model, c.d_model)),
    ("out_b", lambda c: (c.layers_per_block, c.d_model)),
    ("ln1_s", lambda c: (c.layers_per_block, c.d_model)),
    ("ln1_b", lambda c: (c.layers_per_block, c.d_model)),
    ("ln2_s", lambda c: (c.layers_per_block, c.d_model)),
    ("ln2_b", lambda c: (c.layers_per_block, c.d_model)),
    ("ffn_w1", lambda c: (c.layers_per_block, c.d_model, c.d_ff)),
    ("ffn_b1", lambda c: (c.layers_per_block, c.d_ff)),
    ("ffn_w2", lambda c: (c.layers_per_block, c.d_ff, c.d_model)),
    ("ffn_b2", lambda c: (c.layers_per_block, c.d_model)),
    ("temp", lambda c: (c.layers_per_block,)),
)

_TOP_TENSORS = (
    ("gate_w", lambda c: (c.n_blocks * c.d_model, c.n_blocks * c.d_model)),
    ("gate_b", lambda c: (c.n_blocks * c.d_model,)),
    ("exp_w1", lambda c: (c.d_model, c.d_ff)),
    ("exp_b1", lambda c: (c.d_ff,)),
    ("exp_w2", lambda c: (c.d_ff, c.n_tasks)),
    ("exp_b2", lambda c: (c.n_tasks,)),
)


def flatten_spec(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Canonical (name, shape) list — the rust/python weight contract."""
    spec: List[Tuple[str, Tuple[int, ...]]] = []
    for b in range(cfg.n_blocks):
        for name, shape_fn in _BLOCK_TENSORS:
            spec.append((f"block{b}.{name}", shape_fn(cfg)))
    for name, shape_fn in _TOP_TENSORS:
        spec.append((name, shape_fn(cfg)))
    return spec


def init_params(cfg: ModelConfig) -> Dict[str, jnp.ndarray]:
    """Seeded init. Matmul weights ~ N(0, 1/sqrt(fan_in)); biases zero;
    LN scales one; adaptive temperatures near one (the paper's learned
    pre-softmax coefficient)."""
    key = jax.random.PRNGKey(cfg.seed)
    params: Dict[str, jnp.ndarray] = {}
    for name, shape in flatten_spec(cfg):
        key, sub = jax.random.split(key)
        leaf = name.split(".")[-1]
        if leaf in ("qkv_w", "out_w", "ffn_w1", "ffn_w2", "gate_w", "exp_w1", "exp_w2"):
            fan_in = shape[-2]
            arr = jax.random.normal(sub, shape, jnp.float32) / np.sqrt(fan_in)
        elif leaf in ("ln1_s", "ln2_s"):
            arr = jnp.ones(shape, jnp.float32)
        elif leaf == "temp":
            arr = 1.0 + 0.05 * jax.random.normal(sub, shape, jnp.float32)
        else:  # biases
            arr = jnp.zeros(shape, jnp.float32)
        params[name] = arr
    return params


def flatten_params(cfg: ModelConfig, params: Dict[str, jnp.ndarray]) -> List[jnp.ndarray]:
    """Params dict -> flat list in canonical order."""
    return [params[name] for name, _ in flatten_spec(cfg)]


def unflatten_params(cfg: ModelConfig, flat: List[jnp.ndarray]) -> Dict[str, jnp.ndarray]:
    """Flat list (canonical order) -> params dict. Inverse of flatten."""
    spec = flatten_spec(cfg)
    assert len(flat) == len(spec), (len(flat), len(spec))
    out = {}
    for (name, shape), arr in zip(spec, flat):
        assert tuple(arr.shape) == tuple(shape), (name, arr.shape, shape)
        out[name] = arr
    return out


def save_weights_bin(cfg: ModelConfig, params: Dict[str, jnp.ndarray], path: str) -> int:
    """Write little-endian f32 concatenation in canonical order.

    Returns total bytes written. The rust loader slices this buffer by the
    shapes recorded in the manifest.
    """
    total = 0
    with open(path, "wb") as f:
        for name, _ in flatten_spec(cfg):
            arr = np.asarray(params[name], dtype="<f4")
            f.write(arr.tobytes())
            total += arr.nbytes
    return total


def block_params(cfg: ModelConfig, params: Dict[str, jnp.ndarray], b: int) -> Dict[str, jnp.ndarray]:
    """The stacked per-layer tensors of block ``b`` (keys without prefix)."""
    return {name: params[f"block{b}.{name}"] for name, _ in _BLOCK_TENSORS}
