"""The "ONNX Model Conversion" baseline engine (FKE ablation level 1).

This reproduces the *pathology* the paper ascribes to the default
ONNX→TensorRT route (§3.2): a mechanically exported graph, not a
deliberately constructed one. Numerically it computes the same model as
`model.model_forward` (cross-checked in pytest); structurally it carries
the export artifacts a generic converter emits:

* fully **unrolled** layers — L separate subgraphs instead of one scanned
  body (gratuitously verbose IR, the paper's words);
* **split** Q/K/V/O projections — three narrow GEMMs instead of one fused
  QKV GEMM;
* the boolean mask is **rebuilt inside every layer**, broadcast to
  [H, n, n], and applied with the exporter's characteristic double-
  ``where`` (mask scores before softmax, re-mask probabilities after);
* dense candidate×candidate attention — all masked FLOPs are burned;
* softmax spelled out as separate max / sub / exp / sum / div ops;
* head split/merge via explicit transpose-reshape chains per projection.

It takes the same flat weight tuple as the other variants and slices the
stacked per-layer tensors inside the graph.
"""

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .params import block_params
from .kernels import ref


def _naive_softmax(s: jnp.ndarray) -> jnp.ndarray:
    """Softmax spelled out the way exporters serialize it."""
    mx = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(jnp.subtract(s, mx))
    return jnp.divide(e, jnp.sum(e, axis=-1, keepdims=True))


def _naive_layernorm(x, scale, bias, eps=1e-6):
    """LayerNorm as the exported op chain (no rsqrt: sqrt + divide)."""
    mean = jnp.mean(x, axis=-1, keepdims=True)
    diff = jnp.subtract(x, mean)
    var = jnp.mean(jnp.multiply(diff, diff), axis=-1, keepdims=True)
    return jnp.add(jnp.multiply(jnp.divide(diff, jnp.sqrt(var + eps)), scale), bias)


def _naive_heads(x, n_heads):
    n, d = x.shape
    return jnp.transpose(jnp.reshape(x, (n, n_heads, d // n_heads)), (1, 0, 2))


def _naive_layer(cfg: ModelConfig, lp: dict, l: int, x: jnp.ndarray,
                 hist_len: int) -> jnp.ndarray:
    n = x.shape[0]
    m = n - hist_len
    d = cfg.d_model
    h = cfg.n_heads

    ln1 = _naive_layernorm(x, lp["ln1_s"][l], lp["ln1_b"][l])
    # Split projections: slice the stacked fused weight into Q/K/V parts
    # (three GEMMs — what a per-op exporter produces).
    wq, wk, wv = (lp["qkv_w"][l][:, :d], lp["qkv_w"][l][:, d:2 * d],
                  lp["qkv_w"][l][:, 2 * d:])
    bq, bk, bv = (lp["qkv_b"][l][:d], lp["qkv_b"][l][d:2 * d],
                  lp["qkv_b"][l][2 * d:])
    q = _naive_heads(jnp.add(jnp.matmul(ln1, wq), bq), h)
    k = _naive_heads(jnp.add(jnp.matmul(ln1, wk), bk), h)
    v = _naive_heads(jnp.add(jnp.matmul(ln1, wv), bv), h)

    # Mask rebuilt inside the layer and broadcast over heads.
    vis = ref.sumi_mask(hist_len, m)
    vis_h = jnp.broadcast_to(vis[None, :, :], (h, n, n))

    scale = jnp.multiply(lp["temp"][l], 1.0 / jnp.sqrt(jnp.float32(d // h)))
    scores = jnp.multiply(jnp.matmul(q, jnp.transpose(k, (0, 2, 1))), scale)
    scores = jnp.where(vis_h, scores, jnp.float32(ref.NEG_BIAS))   # where #1
    probs = _naive_softmax(scores)
    probs = jnp.where(vis_h, probs, jnp.float32(0.0))              # where #2
    ctx = jnp.matmul(probs, v)
    ctx = jnp.reshape(jnp.transpose(ctx, (1, 0, 2)), (n, d))
    attn = jnp.add(jnp.matmul(ctx, lp["out_w"][l]), lp["out_b"][l])
    x = jnp.add(x, attn)

    ln2 = _naive_layernorm(x, lp["ln2_s"][l], lp["ln2_b"][l])
    ff = jax.nn.gelu(jnp.add(jnp.matmul(ln2, lp["ffn_w1"][l]), lp["ffn_b1"][l]),
                     approximate=False)
    ff = jnp.add(jnp.matmul(ff, lp["ffn_w2"][l]), lp["ffn_b2"][l])
    return jnp.add(x, ff)


def model_forward_naive(cfg: ModelConfig, params: dict, hist: jnp.ndarray,
                        cands: jnp.ndarray) -> jnp.ndarray:
    """Unrolled baseline forward: hist [L, D], cands [M, D] -> [M, T]."""
    lb = cfg.block_len
    m = cands.shape[0]
    outs = []
    for b in range(cfg.n_blocks):
        lp = block_params(cfg, params, b)
        x = jnp.concatenate([hist[b * lb:(b + 1) * lb], cands], axis=0)
        for l in range(cfg.layers_per_block):      # fully unrolled
            x = _naive_layer(cfg, lp, l, x, lb)
        outs.append(x[lb:])

    cat = jnp.concatenate(outs, axis=-1)
    logits = jnp.add(jnp.matmul(cat, params["gate_w"]), params["gate_b"])
    gates = _naive_softmax(
        jnp.transpose(jnp.reshape(logits, (m, cfg.n_blocks, cfg.d_model)), (0, 2, 1))
    )
    gates = jnp.transpose(gates, (0, 2, 1))
    fused = jnp.sum(jnp.multiply(gates, jnp.stack(outs, axis=1)), axis=1)
    hdd = jax.nn.gelu(jnp.add(jnp.matmul(fused, params["exp_w1"]), params["exp_b1"]),
                      approximate=False)
    return jax.nn.sigmoid(jnp.add(jnp.matmul(hdd, params["exp_w2"]), params["exp_b2"]))
