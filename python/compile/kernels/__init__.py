"""L1: Pallas kernels for the FKE plug-ins (mask-aware flash attention,
fused LN+FFN, fused gating+expert head) plus the pure-jnp oracle."""
