"""Fused model head as a Pallas kernel (L1): bit-wise gating fusion
across blocks + expert MLP + sigmoid, in one row-tiled pass over the
candidate rows.

The paper's FKE fuses "the remaining modules of the Transformer" beyond
attention (§3.2); in the Climber architecture the remaining per-request
modules are the gating fusion and the top expert MLP. Unfused, this tail
is 3 GEMMs + softmax + 2 activations with [M, nb*D] intermediates
round-tripping through HBM; fused, a candidate tile makes one trip:

    cat   : [bm, nb*D]   (concat of block outputs — its reshape to
                          [bm, nb, D] *is* the stacked block view)
    gates = softmax_over_blocks(cat @ Wg + bg)
    fused = sum_b gates[:, b, :] * cat[:, b, :]
    out   = sigmoid(gelu(fused @ W1 + b1) @ W2 + b2)    # [bm, T]

VMEM per grid step: weights ((nbD)^2 + D*F + F*T) + one candidate tile —
~1.3 MB at D=128, F=512, nb=2, far under budget.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _head_kernel(cat_ref, gw_ref, gb_ref, w1_ref, b1_ref, w2_ref, b2_ref,
                 o_ref, *, n_blocks: int, d_model: int):
    cat = cat_ref[...]                                     # [bm, nb*D]
    bm = cat.shape[0]
    logits = jnp.dot(cat, gw_ref[...], preferred_element_type=jnp.float32) + gb_ref[...]
    gates = jax.nn.softmax(logits.reshape(bm, n_blocks, d_model), axis=1)
    fused = jnp.sum(gates * cat.reshape(bm, n_blocks, d_model), axis=1)  # [bm, D]
    h = jnp.dot(fused, w1_ref[...], preferred_element_type=jnp.float32) + b1_ref[...]
    h = jax.nn.gelu(h, approximate=False)
    out = jnp.dot(h, w2_ref[...], preferred_element_type=jnp.float32) + b2_ref[...]
    o_ref[...] = jax.nn.sigmoid(out).astype(o_ref.dtype)


def _choose_rows(m: int, cap: int = 128) -> int:
    b = 1
    while b * 2 <= cap and m % (b * 2) == 0:
        b *= 2
    return b


def fused_head(cat: jnp.ndarray, gate_w: jnp.ndarray, gate_b: jnp.ndarray,
               exp_w1: jnp.ndarray, exp_b1: jnp.ndarray,
               exp_w2: jnp.ndarray, exp_b2: jnp.ndarray, *,
               n_blocks: int, d_model: int,
               block_m: int | None = None,
               interpret: bool = True) -> jnp.ndarray:
    """Fused gating + expert head.

    Args:
        cat: [M, nb*D] concatenated block outputs (candidate rows).
        gate_w/gate_b: [nb*D, nb*D] / [nb*D].
        exp_w1/exp_b1: [D, F] / [F]; exp_w2/exp_b2: [F, T] / [T].

    Returns:
        [M, T] task probabilities, matching the unfused head in
        model._head / ref.model_ref's tail.
    """
    m, nbd = cat.shape
    assert nbd == n_blocks * d_model, (nbd, n_blocks, d_model)
    f = exp_w1.shape[1]
    t = exp_w2.shape[1]
    if block_m is None:
        block_m = _choose_rows(m)
    assert m % block_m == 0, (m, block_m)

    kernel = functools.partial(_head_kernel, n_blocks=n_blocks, d_model=d_model)
    return pl.pallas_call(
        kernel,
        grid=(m // block_m,),
        in_specs=[
            pl.BlockSpec((block_m, nbd), lambda i: (i, 0)),  # candidate tile
            pl.BlockSpec((nbd, nbd), lambda i: (0, 0)),      # gate W (resident)
            pl.BlockSpec((nbd,), lambda i: (0,)),
            pl.BlockSpec((d_model, f), lambda i: (0, 0)),    # expert W1
            pl.BlockSpec((f,), lambda i: (0,)),
            pl.BlockSpec((f, t), lambda i: (0, 0)),          # expert W2
            pl.BlockSpec((t,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_m, t), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, t), cat.dtype),
        interpret=interpret,
    )(cat, gate_w, gate_b, exp_w1, exp_b1, exp_w2, exp_b2)


def head_vmem_bytes(n_blocks: int, d_model: int, d_ff: int, n_tasks: int,
                    block_m: int = 128) -> int:
    """Per-grid-step VMEM estimate (bytes) for §Perf."""
    nbd = n_blocks * d_model
    weights = nbd * nbd + nbd + d_model * d_ff + d_ff + d_ff * n_tasks + n_tasks
    tile = block_m * (nbd + n_tasks) + block_m * d_ff
    return 4 * (weights + tile)
