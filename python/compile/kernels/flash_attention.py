"""Mask-aware Flash-Attention as a Pallas kernel (L1).

This is the TPU re-expression of the paper's FKE attention plug-in
(§3.2): a blocked, online-softmax attention whose *tile schedule* encodes
the SUMI mask instead of materializing an [n, n] score matrix and masking
it afterwards.

Mask structure (see kernels/ref.py::sumi_mask): token layout per block is
``[history (hist_len) | candidates (m)]``; history is causal, candidates
attend to all history plus themselves only. With query/key tiles aligned
to the history/candidate boundary this classifies every (q_tile, kv_tile)
pair statically:

  q in history,   kv in history, kv_start >  q_end  -> SKIP   (future)
  q in history,   kv in history, tile on diagonal   -> PARTIAL (causal tri)
  q in history,   kv in history, kv_end <= q_start  -> FULL
  q in history,   kv in candidates                  -> SKIP   (never visible)
  q in candidate, kv in history                     -> FULL
  q in candidate, kv in candidates, same tile       -> PARTIAL (identity)
  q in candidate, kv in candidates, different tile  -> SKIP

The SKIP classes are the paper's mask-aware FLOP savings (the HSTU-style
candidate-parallel trick); on real TPU hardware they are also the
HBM->VMEM transfers never issued. Here the skip is expressed as a
``lax.fori_loop`` upper bound (history rows never read past their own
diagonal tile) plus a ``lax.cond`` over the tile class, so the saving
survives in the lowered HLO even under ``interpret=True``.

TPU adaptation notes (DESIGN.md §Hardware-Adaptation):
  * BlockSpec tiles Q on [block_q, hd] and keeps K/V per-head resident —
    the VMEM analogue of the CUDA kernel's shared-memory staging;
    footprint per grid step = (block_q + 2n) * hd * 4 bytes.
  * tiles are MXU-shaped (multiples of 8x128 lanes when dims allow);
  * interpret=True is mandatory on the CPU PJRT plugin (a real TPU lowering
    emits a Mosaic custom-call the CPU runtime cannot execute).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _choose_block(hist_len: int, m: int, cap: int = 128) -> int:
    """Largest power of two <= cap dividing both hist_len and m, so tiles
    never straddle the history/candidate boundary."""
    b = 1
    while b * 2 <= cap and hist_len % (b * 2) == 0 and m % (b * 2) == 0:
        b *= 2
    return b


def _attn_kernel(q_ref, k_ref, v_ref, t_ref, o_ref, *, hist_len: int,
                 block: int, n_tokens: int):
    """One (head, q_tile) grid step of the mask-aware flash attention."""
    qi = pl.program_id(1)
    q = q_ref[0]                      # [block, hd]
    hd = q.shape[-1]
    t = t_ref[0, 0]                   # adaptive temperature (learned scalar)
    scale = t / jnp.sqrt(jnp.float32(hd))

    q_start = qi * block
    n_hist_tiles = hist_len // block
    q_is_cand = q_start >= hist_len

    # Online-softmax accumulators.
    acc = jnp.zeros((block, hd), jnp.float32)
    m_i = jnp.full((block,), NEG_INF, jnp.float32)
    l_i = jnp.zeros((block,), jnp.float32)

    # Static per-tile element masks (block-local coordinates).
    rows = jax.lax.broadcasted_iota(jnp.int32, (block, block), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (block, block), 1)
    tri_bias = jnp.where(cols <= rows, 0.0, NEG_INF).astype(jnp.float32)  # causal
    eye_bias = jnp.where(cols == rows, 0.0, NEG_INF).astype(jnp.float32)  # self-only

    def visit(j, carry, bias):
        """Fold KV tile j into the online softmax with additive tile bias."""
        acc, m_i, l_i = carry
        k = pl.load(k_ref, (0, pl.dslice(j * block, block), slice(None)))
        v = pl.load(v_ref, (0, pl.dslice(j * block, block), slice(None)))
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale + bias
        m_new = jnp.maximum(m_i, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_i - m_new)
        l_new = l_i * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        return acc_new, m_new, l_new

    def history_rows(carry):
        """q tile inside history: full tiles [0, qi), then the diagonal
        (causal-triangular) tile. KV tiles past qi are never touched."""
        def body(j, c):
            return visit(j, c, 0.0)
        carry = jax.lax.fori_loop(0, qi, body, carry)
        return visit(qi, carry, tri_bias)

    def candidate_rows(carry):
        """q tile inside candidates: all history tiles (full), then the
        aligned candidate tile with identity visibility. Other candidate
        tiles are never touched (candidates don't see each other)."""
        def body(j, c):
            return visit(j, c, 0.0)
        carry = jax.lax.fori_loop(0, n_hist_tiles, body, carry)
        return visit(qi, carry, eye_bias)

    acc, m_i, l_i = jax.lax.cond(
        q_is_cand, candidate_rows, history_rows, (acc, m_i, l_i))

    o_ref[0] = (acc / l_i[:, None]).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    temp: jnp.ndarray, *, hist_len: int,
                    block: int | None = None,
                    interpret: bool = True) -> jnp.ndarray:
    """Mask-aware flash attention over per-head tensors.

    Args:
        q, k, v: [H, n, hd] f32, where n = hist_len + m.
        temp: scalar adaptive temperature (traced; learned per layer).
        hist_len: history prefix length (static); the remaining rows are
            candidates under the SUMI mask.
        block: q/kv tile size; must divide both hist_len and m. Chosen
            automatically (power of two <= 128) when None.
        interpret: run the kernel through the pallas interpreter so it
            lowers to plain HLO (required for the CPU PJRT runtime).

    Returns:
        [H, n, hd] attention output, matching
        ``ref.attention_ref(q, k, v, mask_bias(hist_len, m), temp)``.
    """
    h, n, hd = q.shape
    m = n - hist_len
    assert m > 0, "need at least one candidate row"
    if block is None:
        block = _choose_block(hist_len, m)
    assert hist_len % block == 0 and m % block == 0, (hist_len, m, block)
    n_q_tiles = n // block

    kernel = functools.partial(
        _attn_kernel, hist_len=hist_len, block=block, n_tokens=n)
    t2 = temp.astype(jnp.float32).reshape(1, 1)

    return pl.pallas_call(
        kernel,
        grid=(h, n_q_tiles),
        in_specs=[
            pl.BlockSpec((1, block, hd), lambda i, j: (i, j, 0)),   # q tile
            pl.BlockSpec((1, n, hd), lambda i, j: (i, 0, 0)),       # k (head-resident)
            pl.BlockSpec((1, n, hd), lambda i, j: (i, 0, 0)),       # v (head-resident)
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),              # temperature
        ],
        out_specs=pl.BlockSpec((1, block, hd), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((h, n, hd), q.dtype),
        interpret=interpret,
    )(q, k, v, t2)


def attention_tile_stats(hist_len: int, m: int, block: int | None = None) -> dict:
    """Analytic tile accounting for the §Perf VMEM/FLOP analysis.

    Returns visited vs total (q_tile, kv_tile) pairs and the resulting
    score-FLOP fraction vs dense attention — the number EXPERIMENTS.md
    reports as the kernel's mask-aware saving.
    """
    if block is None:
        block = _choose_block(hist_len, m)
    nq = (hist_len + m) // block
    nh = hist_len // block
    visited = 0
    for qi in range(nq):
        if qi < nh:
            visited += qi + 1          # history: tiles 0..qi
        else:
            visited += nh + 1          # candidate: all history + own tile
    total = nq * nq
    return {
        "block": block,
        "visited_tiles": visited,
        "total_tiles": total,
        "flop_fraction": visited / total,
    }
