"""Pure-jnp oracle for the L1 kernels and the full model semantics.

Everything here is deliberately straightforward jnp — no pallas, no scan —
and is the single source of truth for correctness. The pallas kernels
(`flash_attention.py`, `fused_ffn.py`) and the model variants
(`model.py`, `naive.py`) are all tested against these functions.
"""

import jax
import jax.numpy as jnp

NEG_BIAS = -1e9  # additive mask bias; every row keeps >=1 visible key


def sumi_mask(hist_len: int, m: int) -> jnp.ndarray:
    """Boolean visibility mask of the SUMI (single-user-multi-item) block.

    Token layout per block: ``[h_0 .. h_{hist_len-1}, c_0 .. c_{m-1}]``.

    * history row i sees history keys j <= i (causal);
    * candidate row sees *all* history plus itself only — candidates are
      scored in parallel but must not leak into each other (the HSTU-style
      mask the paper's FKE plug-in implements).
    """
    n = hist_len + m
    i = jnp.arange(n)[:, None]
    j = jnp.arange(n)[None, :]
    hist_causal = (i < hist_len) & (j <= i)
    cand_hist = (i >= hist_len) & (j < hist_len)
    cand_self = (i >= hist_len) & (j == i)
    return hist_causal | cand_hist | cand_self


def mask_bias(hist_len: int, m: int) -> jnp.ndarray:
    """Additive f32 bias form of :func:`sumi_mask` (0 visible / -1e9 not)."""
    return jnp.where(sumi_mask(hist_len, m), 0.0, NEG_BIAS).astype(jnp.float32)


def layernorm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """LayerNorm over the last axis."""
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
    return (x - mean) * jax.lax.rsqrt(var + eps) * scale + bias


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  bias: jnp.ndarray, temp: jnp.ndarray) -> jnp.ndarray:
    """Masked multi-head attention core. q/k/v: [H, n, hd]; bias: [n, n];
    temp: scalar adaptive temperature applied to scores pre-softmax."""
    hd = q.shape[-1]
    scores = jnp.einsum("hqd,hkd->hqk", q, k) * (temp / jnp.sqrt(jnp.float32(hd)))
    scores = scores + bias[None, :, :]
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("hqk,hkd->hqd", probs, v)


def split_heads(x: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    """[n, D] -> [H, n, hd]."""
    n, d = x.shape
    return x.reshape(n, n_heads, d // n_heads).transpose(1, 0, 2)


def merge_heads(x: jnp.ndarray) -> jnp.ndarray:
    """[H, n, hd] -> [n, D]."""
    h, n, hd = x.shape
    return x.transpose(1, 0, 2).reshape(n, h * hd)


def mha_ref(x: jnp.ndarray, qkv_w: jnp.ndarray, qkv_b: jnp.ndarray,
            out_w: jnp.ndarray, out_b: jnp.ndarray, n_heads: int,
            temp: jnp.ndarray, bias: jnp.ndarray) -> jnp.ndarray:
    """Full MHA sublayer on [n, D] input (no residual, no pre-LN)."""
    d = x.shape[-1]
    qkv = x @ qkv_w + qkv_b
    q, k, v = qkv[:, :d], qkv[:, d:2 * d], qkv[:, 2 * d:]
    out = attention_ref(split_heads(q, n_heads), split_heads(k, n_heads),
                        split_heads(v, n_heads), bias, temp)
    return merge_heads(out) @ out_w + out_b


def ffn_ref(x: jnp.ndarray, w1: jnp.ndarray, b1: jnp.ndarray,
            w2: jnp.ndarray, b2: jnp.ndarray) -> jnp.ndarray:
    """Position-wise FFN with exact (erf) gelu."""
    return jax.nn.gelu(x @ w1 + b1, approximate=False) @ w2 + b2


def ln_ffn_ref(x: jnp.ndarray, ln_s: jnp.ndarray, ln_b: jnp.ndarray,
               w1: jnp.ndarray, b1: jnp.ndarray,
               w2: jnp.ndarray, b2: jnp.ndarray) -> jnp.ndarray:
    """Pre-LN FFN sublayer *with* residual: x + FFN(LN(x)).

    This is exactly what the fused LN+FFN pallas kernel computes.
    """
    return x + ffn_ref(layernorm(x, ln_s, ln_b), w1, b1, w2, b2)


def layer_ref(x: jnp.ndarray, lp: dict, l: int, n_heads: int, bias: jnp.ndarray) -> jnp.ndarray:
    """One pre-LN Transformer layer, indexing stacked block params at l."""
    h = x + mha_ref(layernorm(x, lp["ln1_s"][l], lp["ln1_b"][l]),
                    lp["qkv_w"][l], lp["qkv_b"][l], lp["out_w"][l],
                    lp["out_b"][l], n_heads, lp["temp"][l], bias)
    return ln_ffn_ref(h, lp["ln2_s"][l], lp["ln2_b"][l], lp["ffn_w1"][l],
                      lp["ffn_b1"][l], lp["ffn_w2"][l], lp["ffn_b2"][l])


def model_ref(cfg, params: dict, hist: jnp.ndarray, cands: jnp.ndarray) -> jnp.ndarray:
    """Reference forward of the whole Climber-like GR model.

    hist: [L, D] pre-embedded user history; cands: [M, D] candidates.
    Returns per-task probabilities [M, n_tasks].
    """
    from ..params import block_params  # local import to avoid cycle

    lb, m = cfg.block_len, cands.shape[0]
    bias = mask_bias(lb, m)
    outs = []
    for b in range(cfg.n_blocks):
        lp = block_params(cfg, params, b)
        x = jnp.concatenate([hist[b * lb:(b + 1) * lb], cands], axis=0)
        for l in range(cfg.layers_per_block):
            x = layer_ref(x, lp, l, cfg.n_heads, bias)
        outs.append(x[lb:])  # candidate rows [M, D]

    # Bit-wise gating fusion: per-bit softmax over blocks.
    cat = jnp.concatenate(outs, axis=-1)                      # [M, nb*D]
    logits = cat @ params["gate_w"] + params["gate_b"]        # [M, nb*D]
    gates = jax.nn.softmax(
        logits.reshape(m, cfg.n_blocks, cfg.d_model), axis=1)  # [M, nb, D]
    fused = jnp.sum(gates * jnp.stack(outs, axis=1), axis=1)   # [M, D]

    # Expert MLP -> multi-task probabilities.
    h = jax.nn.gelu(fused @ params["exp_w1"] + params["exp_b1"], approximate=False)
    return jax.nn.sigmoid(h @ params["exp_w2"] + params["exp_b2"])
