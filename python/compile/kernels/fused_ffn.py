"""Fused LayerNorm + FFN as a Pallas kernel (L1).

The paper's FKE fuses layer normalization with the adjacent linear
projections into a single TensorRT plug-in (§3.2, Fig 8). Here the whole
pre-LN FFN sublayer — LN, W1, gelu, W2, residual add — is one row-tiled
pallas kernel: a row tile makes a single trip through "VMEM" instead of
six separate op dispatches with intermediate [n, 4D] traffic to HBM.

VMEM accounting per grid step (the §Perf estimate):
    row tile  : block_n * D * 4 B
    weights   : (D*F + F + F*D + D + 2D) * 4 B   (resident across steps)
    activation: block_n * F * 4 B
For D=128, F=512, block_n=128 that is ~1.3 MB — far under the ~16 MB VMEM
budget, leaving room for double-buffering the row stream.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ffn_kernel(x_ref, lns_ref, lnb_ref, w1_ref, b1_ref, w2_ref, b2_ref,
                o_ref, *, eps: float):
    """One row-tile grid step: out = x + gelu(LN(x) @ W1 + b1) @ W2 + b2."""
    x = x_ref[...]                          # [block_n, D]
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
    y = (x - mean) * jax.lax.rsqrt(var + eps) * lns_ref[...] + lnb_ref[...]
    h = jnp.dot(y, w1_ref[...], preferred_element_type=jnp.float32) + b1_ref[...]
    h = jax.nn.gelu(h, approximate=False)
    out = jnp.dot(h, w2_ref[...], preferred_element_type=jnp.float32) + b2_ref[...]
    o_ref[...] = (x + out).astype(o_ref.dtype)


def _choose_rows(n: int, cap: int = 128) -> int:
    """Largest power of two <= cap dividing n."""
    b = 1
    while b * 2 <= cap and n % (b * 2) == 0:
        b *= 2
    return b


def fused_ln_ffn(x: jnp.ndarray, ln_s: jnp.ndarray, ln_b: jnp.ndarray,
                 w1: jnp.ndarray, b1: jnp.ndarray,
                 w2: jnp.ndarray, b2: jnp.ndarray, *,
                 block_n: int | None = None, eps: float = 1e-6,
                 interpret: bool = True) -> jnp.ndarray:
    """Fused pre-LN FFN sublayer with residual.

    Args:
        x: [n, D] activations.
        ln_s, ln_b: [D] layernorm scale/bias.
        w1: [D, F]; b1: [F]; w2: [F, D]; b2: [D].
        block_n: row tile; must divide n (auto power-of-two when None).

    Returns:
        [n, D], matching ``ref.ln_ffn_ref``.
    """
    n, d = x.shape
    f = w1.shape[1]
    if block_n is None:
        block_n = _choose_rows(n)
    assert n % block_n == 0, (n, block_n)

    kernel = functools.partial(_ffn_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),  # row tile
            pl.BlockSpec((d,), lambda i: (0,)),            # ln scale
            pl.BlockSpec((d,), lambda i: (0,)),            # ln bias
            pl.BlockSpec((d, f), lambda i: (0, 0)),        # W1 (resident)
            pl.BlockSpec((f,), lambda i: (0,)),            # b1
            pl.BlockSpec((f, d), lambda i: (0, 0)),        # W2 (resident)
            pl.BlockSpec((d,), lambda i: (0,)),            # b2
        ],
        out_specs=pl.BlockSpec((block_n, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=interpret,
    )(x, ln_s, ln_b, w1, b1, w2, b2)


def ffn_vmem_bytes(n: int, d: int, f: int, block_n: int | None = None) -> int:
    """Per-grid-step VMEM footprint estimate (bytes) for §Perf."""
    if block_n is None:
        block_n = _choose_rows(n)
    weights = d * f + f + f * d + d + 2 * d
    tile = block_n * d * 2          # in + out tile
    act = block_n * f
    return 4 * (weights + tile + act)
