"""Build-time compile path (L1 + L2): the Climber-like GR model in JAX,
its Pallas kernels, and the AOT driver that lowers every engine variant
to HLO text for the rust runtime. Never imported at serve time."""
