"""L2: the Climber-like GR model forward, in its two deliberately-built
engine variants (the FKE ablation's upper levels):

* ``api``   — "TensorRT API Impl.": a compact, deliberately constructed
  graph. ``lax.scan`` over stacked per-layer weights (one compiled layer
  body instead of L unrolled copies), a single fused QKV GEMM, the additive
  SUMI mask computed once per block and reused by every layer.
* ``fused`` — "API + Kernel Fusion": same graph, but the attention core is
  the L1 mask-aware flash-attention pallas kernel and the pre-LN FFN
  sublayer is the L1 fused LN+FFN pallas kernel.

The "ONNX Model Conversion" baseline lives in `naive.py`. All variants take
the *same* flat weight tuple (see params.flatten_spec) so the rust runtime
uploads one device-resident weight set per scenario and shares it across
engines — the analogue of TensorRT engines sharing GPU weight memory.
"""

from typing import List

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .params import block_params, unflatten_params
from .kernels import ref
from .kernels.flash_attention import flash_attention
from .kernels.fused_ffn import fused_ln_ffn


def _mha_api(x, qkv_w, qkv_b, out_w, out_b, n_heads, temp, bias):
    """MHA sublayer with one fused QKV GEMM and dense masked softmax."""
    d = x.shape[-1]
    qkv = x @ qkv_w + qkv_b                       # [n, 3D] single GEMM
    q, k, v = jnp.split(qkv, 3, axis=-1)
    out = ref.attention_ref(
        ref.split_heads(q, n_heads), ref.split_heads(k, n_heads),
        ref.split_heads(v, n_heads), bias, temp)
    return ref.merge_heads(out) @ out_w + out_b


def _mha_fused(x, qkv_w, qkv_b, out_w, out_b, n_heads, temp, hist_len):
    """MHA sublayer with the L1 mask-aware flash-attention kernel.

    No [n, n] bias tensor exists here at all — the mask lives in the
    kernel's tile schedule.
    """
    qkv = x @ qkv_w + qkv_b
    q, k, v = jnp.split(qkv, 3, axis=-1)
    out = flash_attention(
        ref.split_heads(q, n_heads), ref.split_heads(k, n_heads),
        ref.split_heads(v, n_heads), temp, hist_len=hist_len)
    return ref.merge_heads(out) @ out_w + out_b


def _block_forward(cfg: ModelConfig, lp: dict, x: jnp.ndarray,
                   hist_len: int, fused: bool) -> jnp.ndarray:
    """Scan the block's layers over the stacked weights."""
    bias = None if fused else ref.mask_bias(hist_len, x.shape[0] - hist_len)

    def layer(x, w):
        ln1 = ref.layernorm(x, w["ln1_s"], w["ln1_b"])
        if fused:
            attn = _mha_fused(ln1, w["qkv_w"], w["qkv_b"], w["out_w"],
                              w["out_b"], cfg.n_heads, w["temp"], hist_len)
        else:
            attn = _mha_api(ln1, w["qkv_w"], w["qkv_b"], w["out_w"],
                            w["out_b"], cfg.n_heads, w["temp"], bias)
        h = x + attn
        if fused:
            h = fused_ln_ffn(h, w["ln2_s"], w["ln2_b"], w["ffn_w1"],
                             w["ffn_b1"], w["ffn_w2"], w["ffn_b2"])
        else:
            h = ref.ln_ffn_ref(h, w["ln2_s"], w["ln2_b"], w["ffn_w1"],
                               w["ffn_b1"], w["ffn_w2"], w["ffn_b2"])
        return h, None

    x, _ = jax.lax.scan(layer, x, lp)
    return x


# Whether the fused variant also runs the gating+expert head as the L1
# fused-head kernel. Measured OFF on this CPU testbed: the head is a few
# hundred kFLOPs, and the pallas-interpreter's fixed per-call overhead
# (~1.5 ms) exceeds the fusion win below M≈256 — it inverted the Table 4
# `bench` row (2.69 -> 4.20 ms) while being noise at base/long. Kept as
# an opt-in: on real TPU hardware (Mosaic lowering, no interpreter tax)
# the paper's "fuse the remaining modules" choice is the right default.
# See EXPERIMENTS.md §Perf L1 iteration log.
FUSE_HEAD = False


def _head(cfg: ModelConfig, params: dict, outs: List[jnp.ndarray],
          fused: bool = False) -> jnp.ndarray:
    """Bit-wise gating fusion across blocks + expert MLP (identical math
    to ref.model_ref's tail). The fused variant runs it as the L1
    fused-head pallas kernel ("kernel fusion on the remaining modules",
    paper §3.2)."""
    m = outs[0].shape[0]
    cat = jnp.concatenate(outs, axis=-1)
    if fused:
        from .kernels.fused_head import fused_head
        return fused_head(
            cat, params["gate_w"], params["gate_b"], params["exp_w1"],
            params["exp_b1"], params["exp_w2"], params["exp_b2"],
            n_blocks=cfg.n_blocks, d_model=cfg.d_model)
    logits = cat @ params["gate_w"] + params["gate_b"]
    gates = jax.nn.softmax(logits.reshape(m, cfg.n_blocks, cfg.d_model), axis=1)
    fused_o = jnp.sum(gates * jnp.stack(outs, axis=1), axis=1)
    h = jax.nn.gelu(fused_o @ params["exp_w1"] + params["exp_b1"], approximate=False)
    return jax.nn.sigmoid(h @ params["exp_w2"] + params["exp_b2"])


def model_forward(cfg: ModelConfig, params: dict, hist: jnp.ndarray,
                  cands: jnp.ndarray, variant: str) -> jnp.ndarray:
    """Forward one SUMI request: hist [L, D], cands [M, D] -> [M, n_tasks].

    variant: "api" or "fused" (see `naive.py` for "naive").
    """
    assert variant in ("api", "fused"), variant
    lb = cfg.block_len
    outs = []
    for b in range(cfg.n_blocks):
        lp = block_params(cfg, params, b)
        x = jnp.concatenate([hist[b * lb:(b + 1) * lb], cands], axis=0)
        x = _block_forward(cfg, lp, x, lb, fused=(variant == "fused"))
        outs.append(x[lb:])
    return _head(cfg, params, outs, fused=(variant == "fused" and FUSE_HEAD))


def make_flat_fn(cfg: ModelConfig, variant: str):
    """The AOT entrypoint: f(*flat_weights, hist, cands) -> (scores,).

    Flat-tuple signature (canonical order) is the rust runtime contract.
    Returns a 1-tuple so the HLO root is a tuple (see aot.to_hlo_text).
    """
    if variant == "naive":
        from .naive import model_forward_naive as fwd
    else:
        fwd = lambda c, p, h, m: model_forward(c, p, h, m, variant)

    def fn(*args):
        flat, (hist, cands) = list(args[:-2]), args[-2:]
        params = unflatten_params(cfg, flat)
        return (fwd(cfg, params, hist, cands),)

    return fn
