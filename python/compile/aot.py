"""AOT compile path: lower every (scenario, variant, M-profile) engine to
HLO **text**, dump the shared weight blob, the artifact manifest, and
numeric test vectors for the rust runtime.

Run once via ``make artifacts``; python never appears on the request path.

Why HLO text: the image's xla_extension 0.5.1 rejects serialized
HloModuleProto from jax>=0.5 (64-bit instruction ids); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs (under --out-dir):
    manifest.json               artifact index (the rust-side contract)
    weights_<scenario>.bin      f32 LE concat in params.flatten_spec order
    <scenario>_<variant>_m<M>.hlo.txt
    tv_<scenario>_<variant>_m<M>_<i>.bin  test vectors (tiny scenario)
"""

import argparse
import json
import os
import struct
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .config import SCENARIOS, VARIANTS, ModelConfig, model_flops
from .params import flatten_spec, init_params, flatten_params, save_weights_bin
from .model import make_flat_fn

TV_MAGIC = 0x464C5456  # "FLTV"


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def lower_model(cfg: ModelConfig, variant: str, m: int) -> str:
    """Lower f(*weights, hist, cands) for a fixed candidate profile M."""
    fn = make_flat_fn(cfg, variant)
    specs = [jax.ShapeDtypeStruct(shape, jnp.float32) for _, shape in flatten_spec(cfg)]
    specs.append(jax.ShapeDtypeStruct((cfg.seq_len, cfg.d_model), jnp.float32))
    specs.append(jax.ShapeDtypeStruct((m, cfg.d_model), jnp.float32))
    return to_hlo_text(jax.jit(fn).lower(*specs))


def write_testvector(path: str, tensors) -> None:
    """Binary tensor container: magic, version, count, then per tensor
    (name_len, name, ndim, dims i64, f32 LE data). Mirrored by
    rust/src/manifest/testvec.rs."""
    with open(path, "wb") as f:
        f.write(struct.pack("<III", TV_MAGIC, 1, len(tensors)))
        for name, arr in tensors:
            arr = np.asarray(arr, dtype="<f4")
            name_b = name.encode()
            f.write(struct.pack("<I", len(name_b)))
            f.write(name_b)
            f.write(struct.pack("<I", arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<q", d))
            f.write(arr.tobytes())


def build_scenario(cfg: ModelConfig, out_dir: str, variants, manifest: dict,
                   n_testvectors: int) -> None:
    print(f"[aot] scenario {cfg.name}: init params (seed {cfg.seed})")
    params = init_params(cfg)
    wpath = f"weights_{cfg.name}.bin"
    nbytes = save_weights_bin(cfg, params, os.path.join(out_dir, wpath))
    manifest["scenarios"][cfg.name] = {
        "seq_len": cfg.seq_len,
        "n_blocks": cfg.n_blocks,
        "layers_per_block": cfg.layers_per_block,
        "d_model": cfg.d_model,
        "n_heads": cfg.n_heads,
        "n_tasks": cfg.n_tasks,
        "d_ff": cfg.d_ff,
        "block_len": cfg.block_len,
        "m_profiles": list(cfg.m_profiles),
        "native_m": cfg.native_m,
        "seed": cfg.seed,
        "weights_file": wpath,
        "weights_bytes": nbytes,
        "weights": [{"name": n, "shape": list(s)} for n, s in flatten_spec(cfg)],
    }

    flat = flatten_params(cfg, params)
    key = jax.random.PRNGKey(cfg.seed + 99)

    for variant in variants:
        # naive is an FKE-ablation baseline: export at native M only
        # (the paper builds one ONNX engine per scenario, not per profile).
        ms = [cfg.native_m] if variant == "naive" else list(cfg.m_profiles)
        for m in ms:
            t0 = time.time()
            hlo = lower_model(cfg, variant, m)
            path = f"{cfg.name}_{variant}_m{m}.hlo.txt"
            with open(os.path.join(out_dir, path), "w") as f:
                f.write(hlo)
            print(f"[aot] {path}: {len(hlo) / 1e6:.2f} MB HLO text "
                  f"({time.time() - t0:.1f}s)")
            manifest["models"].append({
                "scenario": cfg.name,
                "variant": variant,
                "m": m,
                "path": path,
                "flops": model_flops(cfg, m),
                "n_weight_inputs": len(flat),
            })

            # Test vectors: executed in python, checked by the rust runtime
            # integration tests. Only for cheap scenarios.
            if n_testvectors > 0 and cfg.name in ("tiny", "bench"):
                fn = jax.jit(make_flat_fn(cfg, variant))
                for i in range(n_testvectors):
                    key, k1, k2 = jax.random.split(key, 3)
                    hist = jax.random.normal(k1, (cfg.seq_len, cfg.d_model), jnp.float32)
                    cands = jax.random.normal(k2, (m, cfg.d_model), jnp.float32)
                    (scores,) = fn(*flat, hist, cands)
                    tv_path = f"tv_{cfg.name}_{variant}_m{m}_{i}.bin"
                    write_testvector(os.path.join(out_dir, tv_path), [
                        ("hist", hist), ("cands", cands), ("scores", scores)])
                    manifest["testvectors"].append({
                        "scenario": cfg.name, "variant": variant, "m": m,
                        "path": tv_path,
                    })


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--scenarios", default="tiny,bench",
                    help="comma list from: " + ",".join(SCENARIOS))
    ap.add_argument("--variants", default=",".join(VARIANTS))
    ap.add_argument("--testvectors", type=int, default=2,
                    help="test vectors per (variant, M) for tiny/bench")
    args = ap.parse_args(argv)

    os.makedirs(args.out_dir, exist_ok=True)
    manifest_path = os.path.join(args.out_dir, "manifest.json")
    # Incremental: merge into an existing manifest so `make artifacts`
    # (tiny,bench) and `make artifacts-full` (adds base,long) compose.
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            manifest = json.load(f)
        manifest.setdefault("scenarios", {})
        manifest.setdefault("models", [])
        manifest.setdefault("testvectors", [])
    else:
        manifest = {"version": 1, "scenarios": {}, "models": [], "testvectors": []}

    variants = [v.strip() for v in args.variants.split(",") if v.strip()]
    for name in [s.strip() for s in args.scenarios.split(",") if s.strip()]:
        cfg = SCENARIOS[name]
        # drop stale entries for this scenario before regenerating
        manifest["models"] = [e for e in manifest["models"]
                              if not (e["scenario"] == name and e["variant"] in variants)]
        manifest["testvectors"] = [e for e in manifest["testvectors"]
                                   if not (e["scenario"] == name and e["variant"] in variants)]
        build_scenario(cfg, args.out_dir, variants, manifest, args.testvectors)

    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"[aot] wrote {manifest_path}: {len(manifest['models'])} engines, "
          f"{len(manifest['testvectors'])} test vectors")


if __name__ == "__main__":
    main()
